//! Flow-level event-driven simulator (§6.1 "Simulator").
//!
//! Runs the same policy logic as the controller over a simulated WAN: jobs
//! arrive, their DAG stages compute and submit coflows, the policy
//! reallocates rates on every scheduling round (coflow arrival, FlowGroup /
//! coflow completion, significant WAN events), and FlowGroups drain at the
//! allocated rates between rounds. As in the paper, controller-agent
//! communication is instantaneous unless a coordination delay is configured
//! (used to mimic the testbed's feedback loops).
//!
//! All round machinery — the active-coflow table, ρ-dampened WAN-event
//! filtering, allocation clamping, feasibility checks, the Γ-cache — lives
//! in the shared [`crate::engine::RoundEngine`]; this module only owns the
//! virtual clock, the job DAGs, and the event heap.

pub mod job;
pub mod report;

pub use job::{Job, Stage};
pub use report::{foi, foi_volume_correlation, CoflowRecord, JobRecord, Report};

use crate::coflow::{Coflow, CoflowId, ServiceClass};
use crate::engine::{EngineConfig, ShardedEngine};
use crate::net::dynamics::AnnouncedWindow;
use crate::net::telemetry::{self, TelemetryConfig};
use crate::net::{LinkEvent, Wan};
use crate::scheduler::{CoflowRates, CoflowState, Policy, RoundTrigger};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

/// Simulator knobs.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Bandwidth-fluctuation threshold ρ for re-optimization (§3.1.3).
    pub rho: f64,
    /// Latency between coflow submission and participation in scheduling
    /// (models the controller feedback loop; 0 = the paper's simulator).
    pub coordination_delay_s: f64,
    /// Hard stop (simulated seconds).
    pub max_time: f64,
    /// Verify allocation feasibility every round (tests/debug).
    pub check_feasibility: bool,
    /// Worker threads for parallel component solves (see
    /// [`EngineConfig::workers`]); results are bit-identical for any value.
    pub workers: usize,
    /// WAN telemetry & capacity estimation ([`crate::net::telemetry`]).
    /// Under the oracle default the simulator behaves exactly as before:
    /// the scheduler sees ground-truth capacities. Any other estimator
    /// splits the planes: ground truth stays in the simulator (fed by
    /// `net/dynamics`), the scheduler sees only capacity *beliefs* fused
    /// from what agents could actually observe — throughput capped by
    /// their own allocation — plus active probes on stale edges.
    pub telemetry: TelemetryConfig,
    /// Control-plane shards ([`EngineConfig::shards`]). `1` (default) is
    /// the plain single-engine control plane, bit-identical to previous
    /// behavior; `> 1` splits the active set across engine shards that
    /// round concurrently (allocations stay identical — property-pinned).
    pub shards: usize,
    /// Controller crash/restart injection (the `controller_chaos` axis).
    /// `None` (default) is the always-up control plane — bit-identical to
    /// previous behavior.
    pub chaos: Option<ChaosConfig>,
}

/// One crash/restart cycle for the simulator. The default target is the
/// controller: it dies at `kill_t` (no scheduling rounds; agents keep
/// draining their last-known allocation scaled by `degraded_scale`;
/// submissions defer) and is back — state recovered per `mode` — at
/// `restart_t`. Data-plane targets instead fail one *site*: its traffic
/// stalls at `kill_t`, the controller notices after `detection_s` (parks
/// the touched coflows, re-solves the survivors), and the site heals at
/// `restart_t` (parked coflows resume from their preserved progress).
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    pub kill_t: f64,
    pub restart_t: f64,
    pub mode: RecoveryMode,
    /// Degraded-mode drain factor in `[0, 1]`: the conservative fair-share
    /// fallback agents enforce while the controller is unreachable (the
    /// testbed agents use 0.5 of the last-known envelope).
    pub degraded_scale: f64,
    /// What fails at `kill_t` (default: [`ChaosTarget::Controller`]).
    pub target: ChaosTarget,
    /// Failure-detection latency for data-plane targets: simulated seconds
    /// between the failure and the controller declaring the site down
    /// (models the liveness deadline for an agent kill, or the
    /// stall-watchdog horizon for a partition). Ignored for the
    /// controller target — agents detect controller silence themselves.
    pub detection_s: f64,
}

/// What a [`ChaosConfig`] cycle takes down.
///
/// `Agent` and `Partition` behave identically at flow level (the site's
/// traffic stops, detection parks it, healing un-parks it); they exist as
/// distinct variants because they model different *detectors* — an agent
/// kill is caught by the controller's liveness deadline, a data-plane
/// partition by the stall watchdog (heartbeats still flow on the control
/// channel) — and therefore carry different natural `detection_s` values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosTarget {
    /// The controller process (the `controller_chaos` axis).
    Controller,
    /// One site's agent process dies: all its traffic, both directions.
    Agent { site: usize },
    /// One site's data plane is severed while its agent stays up.
    Partition { site: usize },
}

impl ChaosConfig {
    pub fn new(kill_t: f64, restart_t: f64, mode: RecoveryMode) -> ChaosConfig {
        assert!(kill_t.is_finite() && restart_t.is_finite() && kill_t < restart_t);
        ChaosConfig {
            kill_t,
            restart_t,
            mode,
            degraded_scale: 0.5,
            target: ChaosTarget::Controller,
            detection_s: 1.0,
        }
    }

    /// Re-aim the cycle at a data-plane target.
    pub fn with_target(mut self, target: ChaosTarget) -> ChaosConfig {
        self.target = target;
        self
    }

    /// Override the data-plane failure-detection latency.
    pub fn with_detection_s(mut self, detection_s: f64) -> ChaosConfig {
        assert!(detection_s.is_finite() && detection_s >= 0.0);
        self.detection_s = detection_s;
        self
    }
}

/// What the restarted controller recovers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryMode {
    /// State reconstructed from agent resync reports: remaining volumes
    /// survive (no transfer restarts from zero); capacity beliefs and
    /// solver caches are process state and reset.
    Resync,
    /// Strawman baseline with no resync protocol: every unfinished
    /// transfer restarts from its full volume.
    FromZero,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            rho: crate::scheduler::DEFAULT_RHO,
            coordination_delay_s: 0.0,
            max_time: 1e7,
            check_feasibility: cfg!(debug_assertions),
            workers: crate::engine::default_workers(),
            telemetry: TelemetryConfig::default(),
            shards: 1,
            chaos: None,
        }
    }
}

#[derive(Clone, Debug)]
enum EvKind {
    JobArrival(usize),
    /// All deps of (job, stage) finished and compute elapsed; submit the
    /// stage's coflow.
    CoflowSubmit { job: usize, stage: usize },
    /// Force-complete a stage (fallback path for rejected coflows and
    /// WAN-free stages finishing asynchronously).
    StageDone { job: usize, stage: usize },
    /// A submitted coflow becomes schedulable after the coordination delay.
    Activate(Box<CoflowState>),
    Wan(LinkEvent),
    /// Telemetry sampling tick (belief mode only): agents report achieved
    /// per-edge throughput, stale edges get probed, belief changes flow
    /// through the engine's ρ gate. Self-rescheduling while the workload
    /// is live.
    Telemetry,
    /// Announced-maintenance capacity prior on directed edge (u, v) taking
    /// effect now, pinned against samples/probes until `until`;
    /// `gbps = None` restores the base-capacity prior at the window end.
    Prior { u: usize, v: usize, gbps: Option<f64>, until: f64 },
    /// Controller dies (chaos axis): rounds stop, drains degrade.
    ChaosKill,
    /// Controller restarts and recovers per [`ChaosConfig::mode`].
    ChaosRestart,
    /// The controller's failure detector fires for a data-plane target
    /// (`detection_s` after the kill): the site is declared down, its
    /// coflows park, survivors re-solve. Ignored if the site already
    /// healed — a blip shorter than the detector never surfaces.
    AgentDown { site: usize },
}

#[derive(Clone, Debug)]
struct TimedEvent {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for TimedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for TimedEvent {}
impl Ord for TimedEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earliest time first, then insertion order. `total_cmp`
        // keeps the heap invariant even for exotic floats (`push_event`
        // rejects non-finite times before they get here).
        other.t.total_cmp(&self.t).then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for TimedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

struct JobState {
    deps_remaining: Vec<usize>,
    stage_done: Vec<bool>,
}

/// The simulator.
pub struct Simulation {
    engine: ShardedEngine,
    cfg: SimConfig,
    /// Ground-truth WAN, present only in belief mode (non-oracle
    /// estimator): `net/dynamics` events apply here, and the engine's WAN
    /// becomes a belief fed through telemetry sampling. `None` under the
    /// oracle — the engine's WAN *is* the truth, exactly as before.
    truth: Option<Wan>,
    /// Edges whose true capacity has drifted ≥ ρ from the scheduler's
    /// believed capacity, keyed to the episode start time — resolved (and
    /// its reaction latency booked) once the belief closes back within ρ.
    pending_stale: HashMap<usize, f64>,
    now: f64,
    seq: u64,
    events: BinaryHeap<TimedEvent>,
    /// Application events (arrivals, submits, stage/coflow activations)
    /// still in the heap. When this hits zero with an empty engine, the
    /// workload can never make progress again and the run ends — trailing
    /// WAN events are not replayed against an idle network.
    pending_app_events: usize,
    /// Ground-truth WAN events still in the heap (belief mode uses this to
    /// decide whether telemetry ticks can still learn anything).
    pending_wan_events: usize,
    jobs: Vec<Job>,
    job_states: Vec<JobState>,
    /// Coflow id -> (job idx, stage idx).
    owners: HashMap<CoflowId, (usize, usize)>,
    next_coflow_id: CoflowId,
    report: Report,
    record_idx: HashMap<CoflowId, usize>,
    /// Controller-chaos state: true between `ChaosKill` and
    /// `ChaosRestart`. No rounds run, submissions defer, telemetry is
    /// lost, and agents drain degraded-scaled last-known allocations.
    down: bool,
    /// The next round is the restarted controller's reconstruction round;
    /// its wall-clock cost books as [`Report::recovery_round_s`].
    pending_recovery: bool,
    /// Data-plane chaos state: the currently-failed site, if any. Its
    /// traffic drains at zero (ground truth: the endpoint is gone) from
    /// the kill until the heal, whether or not the controller has noticed.
    dead_site: Option<usize>,
    /// True once the failure detector fired for `dead_site` (the engine
    /// holds the site down and the touched coflows are parked).
    site_detected: bool,
    /// True once any stream (rate-floor) coflow was admitted — gates the
    /// per-advance violation-seconds scan so class-free runs pay nothing.
    has_streams: bool,
}

impl Simulation {
    pub fn new(wan: Wan, policy: Box<dyn Policy>, cfg: SimConfig) -> Simulation {
        let name = policy.name().to_string();
        let truth = if cfg.telemetry.is_oracle() { None } else { Some(wan.clone()) };
        let engine = ShardedEngine::new(
            wan,
            policy,
            EngineConfig {
                rho: cfg.rho,
                check_feasibility: cfg.check_feasibility,
                workers: cfg.workers,
                telemetry: cfg.telemetry.clone(),
                shards: cfg.shards,
                ..Default::default()
            },
        );
        let mut sim = Simulation {
            engine,
            cfg,
            truth,
            pending_stale: HashMap::new(),
            now: 0.0,
            seq: 0,
            events: BinaryHeap::new(),
            pending_app_events: 0,
            pending_wan_events: 0,
            jobs: Vec::new(),
            job_states: Vec::new(),
            owners: HashMap::new(),
            next_coflow_id: 1,
            report: Report { policy: name, ..Default::default() },
            record_idx: HashMap::new(),
            down: false,
            pending_recovery: false,
            dead_site: None,
            site_detected: false,
            has_streams: false,
        };
        if sim.truth.is_some() {
            let t = sim.cfg.telemetry.sample_interval_s.max(1e-3);
            sim.push_event(t, EvKind::Telemetry);
        }
        if let Some(chaos) = sim.cfg.chaos.clone() {
            assert!(
                chaos.kill_t.is_finite()
                    && chaos.restart_t.is_finite()
                    && chaos.kill_t < chaos.restart_t,
                "chaos kill must precede restart"
            );
            assert!(
                (0.0..=1.0).contains(&chaos.degraded_scale),
                "degraded_scale must be in [0, 1]"
            );
            sim.push_event(chaos.kill_t, EvKind::ChaosKill);
            sim.push_event(chaos.restart_t, EvKind::ChaosRestart);
        }
        sim
    }

    /// Access the WAN (e.g. to inspect capacities in tests).
    pub fn wan(&self) -> &Wan {
        self.engine.wan()
    }

    /// The (sharded) control-plane front-end driving this simulation.
    pub fn engine(&self) -> &ShardedEngine {
        &self.engine
    }

    fn push_event(&mut self, t: f64, kind: EvKind) {
        assert!(t.is_finite(), "non-finite event time {t} for {kind:?}");
        match kind {
            EvKind::Wan(_) => self.pending_wan_events += 1,
            EvKind::Telemetry
            | EvKind::Prior { .. }
            | EvKind::ChaosKill
            | EvKind::ChaosRestart
            | EvKind::AgentDown { .. } => {}
            _ => self.pending_app_events += 1,
        }
        self.seq += 1;
        self.events.push(TimedEvent { t, seq: self.seq, kind });
    }

    /// Register a job before (or during) the run.
    pub fn add_job(&mut self, job: Job) {
        job.validate().expect("invalid job DAG");
        let idx = self.jobs.len();
        self.push_event(job.arrival.max(self.now), EvKind::JobArrival(idx));
        self.job_states.push(JobState {
            deps_remaining: job.stages.iter().map(|s| s.deps.len()).collect(),
            stage_done: vec![false; job.stages.len()],
        });
        self.report.jobs.push(JobRecord {
            id: job.id,
            arrival: job.arrival,
            finish: None,
            volume: job.total_volume(),
        });
        self.jobs.push(job);
    }

    /// Schedule a WAN event at absolute time `t`. In belief mode this is a
    /// **ground-truth** change: structural events are observable and reach
    /// the scheduler immediately, bandwidth changes only reach it through
    /// telemetry sampling.
    pub fn add_wan_event(&mut self, t: f64, ev: LinkEvent) {
        self.push_event(t, EvKind::Wan(ev));
    }

    /// Register an announced maintenance window
    /// ([`crate::net::dynamics::AnnouncedWindow`]): the announced capacity
    /// lands as an authoritative estimator prior at **announce time** —
    /// the scheduler proactively drains the link `lead_s` ahead of the
    /// window, SWAN planned-update style, so the drain itself causes zero
    /// discovery latency (at the cost of under-using the link during the
    /// lead). The base-capacity prior lands at the window end. Inert under
    /// the oracle (the truth events already carry everything).
    pub fn add_announcement(&mut self, w: &AnnouncedWindow) {
        if self.truth.is_none() {
            return;
        }
        self.push_event(
            w.announce_t.min(w.start_t).max(self.now),
            EvKind::Prior { u: w.u, v: w.v, gbps: Some(w.gbps), until: w.end_t },
        );
        self.push_event(
            w.end_t.max(self.now),
            EvKind::Prior { u: w.u, v: w.v, gbps: None, until: 0.0 },
        );
    }

    /// Convenience: add all jobs and run to completion.
    pub fn run_jobs(&mut self, jobs: Vec<Job>) -> Report {
        for j in jobs {
            self.add_job(j);
        }
        self.run()
    }

    /// Minimum CCT of a coflow alone on the *full* WAN (for slowdown and
    /// deadline metrics).
    pub fn standalone_min_cct(&self, st: &CoflowState) -> f64 {
        self.engine.standalone_min_cct(st)
    }

    /// Current total rate (Gbps) of a coflow, for live inspection (used by
    /// the failure case study, Fig 10).
    pub fn coflow_rate(&self, id: CoflowId) -> f64 {
        self.engine.coflow_rate(id)
    }

    /// The per-(group, path) rates allocated to a coflow in the last round
    /// (used by the sim↔controller parity tests).
    pub fn allocation(&self, id: CoflowId) -> Option<CoflowRates> {
        self.engine.coflow_rates(id)
    }

    /// Drive the simulation until all jobs finish or `max_time`.
    pub fn run(&mut self) -> Report {
        self.run_until(f64::INFINITY)
    }

    /// Run until simulated time `stop` (or completion). Can be called
    /// repeatedly for timeline inspection (Fig 10 throughput traces).
    pub fn run_until(&mut self, stop: f64) -> Report {
        let mut needs_round: Option<RoundTrigger> = None;
        let mut starving_rounds = 0usize;
        loop {
            if self.engine.is_empty() && self.pending_app_events == 0 {
                // All workload delivered and drained: nothing left that can
                // make progress. Trailing WAN events (e.g. a generated
                // dynamics stream outliving the jobs) are deliberately not
                // replayed against the idle network — they would only
                // inflate makespan and dilute the reaction-latency stats.
                break;
            }
            let completion = self.engine.next_completion(self.now);
            let next_event_t = self.events.peek().map(|e| e.t);
            let target = match (completion, next_event_t) {
                (Some(c), Some(e)) => c.min(e),
                (Some(c), None) => c,
                (None, Some(e)) => e,
                (None, None) => {
                    if self.engine.is_empty() || starving_rounds > 0 {
                        break;
                    }
                    // Active coflows, no rates, no events: force one round;
                    // if still no progress the WAN is partitioned for them.
                    // Not booked as a WAN reaction — no WAN event fired.
                    starving_rounds += 1;
                    self.round_inner(RoundTrigger::WanChange, false);
                    continue;
                }
            };
            if target > stop {
                self.advance(stop.min(self.cfg.max_time));
                break;
            }
            if target > self.cfg.max_time {
                log::warn!("hit max_time with {} active coflows", self.engine.len());
                break;
            }
            starving_rounds = 0;
            self.advance(target);

            if self.process_completions() {
                needs_round = Some(RoundTrigger::CoflowFinish);
            }
            while self.events.peek().map(|e| e.t <= self.now + 1e-12).unwrap_or(false) {
                let ev = self.events.pop().unwrap();
                match ev.kind {
                    EvKind::Wan(_) => self.pending_wan_events -= 1,
                    EvKind::Telemetry
                    | EvKind::Prior { .. }
                    | EvKind::ChaosKill
                    | EvKind::ChaosRestart
                    | EvKind::AgentDown { .. } => {}
                    _ => self.pending_app_events -= 1,
                }
                match ev.kind {
                    EvKind::JobArrival(j) => self.on_job_arrival(j),
                    EvKind::CoflowSubmit { job, stage } => {
                        if self.down {
                            // Controller unreachable: the framework's
                            // submit RPC retries until the restart.
                            let t = self.cfg.chaos.as_ref().unwrap().restart_t;
                            self.push_event(t, EvKind::CoflowSubmit { job, stage });
                        } else if self.on_coflow_submit(job, stage) {
                            needs_round = Some(RoundTrigger::CoflowArrival);
                        }
                    }
                    EvKind::StageDone { job, stage } => self.complete_stage(job, stage),
                    EvKind::Activate(state) => {
                        if self.down {
                            let t = self.cfg.chaos.as_ref().unwrap().restart_t;
                            self.push_event(t, EvKind::Activate(state));
                        } else {
                            self.engine.insert(*state);
                            needs_round = Some(RoundTrigger::CoflowArrival);
                        }
                    }
                    EvKind::Wan(wev) => {
                        // ρ-dampened filtering (§3.1.3) and path recompute
                        // (§4.4) happen inside the engine; sub-threshold
                        // fluctuations clamp without a round. In belief
                        // mode only structural events reach the engine —
                        // bandwidth truth must be *estimated*.
                        self.report.wan_events += 1;
                        if self.truth.is_some() {
                            if let Some(t) = self.on_truth_event(&wev) {
                                needs_round = Some(t);
                            }
                        } else {
                            let now = self.now;
                            let reaction = self.engine.handle_wan_event_at(&wev, now);
                            if matches!(wev, LinkEvent::SetBandwidth(..))
                                && reaction == crate::engine::WanReaction::Reoptimize
                            {
                                // The oracle reacts to a qualifying
                                // capacity change at the instant it
                                // happens: a zero-latency staleness
                                // episode, for comparability with the
                                // estimators' reaction-latency metric.
                                self.report.stale_events += 1;
                                self.report.stale_resolved += 1;
                            }
                            if let Some(t) = reaction.trigger() {
                                needs_round = Some(t);
                            }
                        }
                    }
                    EvKind::Telemetry => {
                        // While the controller is down no agent reports
                        // arrive; the tick is lost, not queued — beliefs
                        // are re-derived after the restart, not replayed
                        // (matching the testbed's crash_reset).
                        if !self.down {
                            if let Some(t) = self.telemetry_tick() {
                                needs_round = Some(t);
                            }
                        }
                        // Reschedule only while the workload is live AND a
                        // tick can still learn or drain something: truth
                        // events remain, something is draining, or probing
                        // can close a belief/truth gap. Without this gate
                        // a genuinely starved coflow (partitioned WAN)
                        // would keep the heap non-empty and spin the loop
                        // to max_time one tick at a time.
                        let live = self.pending_app_events > 0 || !self.engine.is_empty();
                        let useful = self.pending_app_events > 0
                            || self.pending_wan_events > 0
                            || self.engine.next_completion(self.now).is_some()
                            || (self.cfg.telemetry.probe_after_s > 0.0
                                && self.beliefs_diverge_from_truth());
                        if self.truth.is_some() && live && useful {
                            let dt = self.cfg.telemetry.sample_interval_s.max(1e-3);
                            self.push_event(self.now + dt, EvKind::Telemetry);
                        }
                    }
                    EvKind::Prior { u, v, gbps, until } => {
                        if let Some(t) = self.apply_prior(u, v, gbps, until) {
                            needs_round = Some(t);
                        }
                    }
                    EvKind::ChaosKill => {
                        let chaos =
                            self.cfg.chaos.clone().expect("kill without chaos config");
                        match chaos.target {
                            ChaosTarget::Controller => {
                                self.down = true;
                                self.report.chaos_kills += 1;
                                let mut inflight = 0.0;
                                self.engine.visit_allocations(|cs, _| {
                                    inflight += cs.total_remaining()
                                });
                                self.report.inflight_at_kill_gbit += inflight;
                            }
                            ChaosTarget::Agent { site }
                            | ChaosTarget::Partition { site } => {
                                // The site's traffic stops now; the
                                // controller only notices detection_s
                                // later (liveness deadline / stall
                                // watchdog).
                                self.dead_site = Some(site);
                                self.site_detected = false;
                                self.push_event(
                                    self.now + chaos.detection_s,
                                    EvKind::AgentDown { site },
                                );
                            }
                        }
                    }
                    EvKind::AgentDown { site } => {
                        // Only a still-dead site is declared down: a blip
                        // shorter than the detector never surfaces.
                        if self.dead_site == Some(site) && !self.site_detected {
                            self.site_detected = true;
                            let chaos = self.cfg.chaos.as_ref().unwrap();
                            self.report.agent_downs += 1;
                            self.report.agent_detection_s += self.now - chaos.kill_t;
                            let before = self.engine.parked_down_count();
                            let reaction = self.engine.set_site_down(
                                site,
                                crate::engine::SitePartition::Full,
                                self.now,
                            );
                            self.report.agent_parked +=
                                self.engine.parked_down_count() - before;
                            if let Some(t) = reaction.trigger() {
                                needs_round = Some(t);
                            }
                        }
                    }
                    EvKind::ChaosRestart
                        if !matches!(
                            self.cfg.chaos.as_ref().map(|c| c.target),
                            Some(ChaosTarget::Controller) | None
                        ) =>
                    {
                        // Data-plane heal: traffic can move again; if the
                        // down state surfaced, un-park through the engine
                        // and let the reconstruction round re-admit the
                        // parked coflows from their preserved progress.
                        self.dead_site = None;
                        if self.site_detected {
                            self.site_detected = false;
                            let chaos = self.cfg.chaos.as_ref().unwrap();
                            let site = match chaos.target {
                                ChaosTarget::Agent { site }
                                | ChaosTarget::Partition { site } => site,
                                ChaosTarget::Controller => unreachable!(),
                            };
                            let reaction = self.engine.set_site_up(site, self.now);
                            if let Some(t) = reaction.trigger() {
                                needs_round = Some(t);
                            }
                        }
                    }
                    EvKind::ChaosRestart => {
                        let chaos =
                            self.cfg.chaos.clone().expect("restart without chaos config");
                        self.report.chaos_downtime_s += chaos.restart_t - chaos.kill_t;
                        if chaos.mode == RecoveryMode::FromZero {
                            // Strawman: no resync protocol. The rebuilt
                            // controller only knows each transfer's
                            // requested volume, so every unfinished
                            // transfer restarts from zero.
                            let mut ids: Vec<CoflowId> = Vec::new();
                            self.engine.visit_allocations(|cs, _| ids.push(cs.id));
                            for id in ids {
                                if let Some(cs) = self.engine.get_mut(id) {
                                    for gi in 0..cs.groups.len() {
                                        cs.remaining[gi] = cs.groups[gi].volume;
                                    }
                                }
                                self.engine.mark_dirty(id);
                            }
                        }
                        // What the restarted controller believes is still
                        // in flight (after any from-zero re-inflation):
                        // the denominator of the preserved fraction.
                        let mut inflight = 0.0;
                        self.engine
                            .visit_allocations(|cs, _| inflight += cs.total_remaining());
                        self.report.inflight_at_restart_gbit += inflight;
                        self.engine.crash_reset(self.now);
                        self.down = false;
                        self.pending_recovery = true;
                        needs_round = Some(RoundTrigger::CoflowArrival);
                    }
                }
            }

            if self.down {
                // No controller, no rounds: completions and WAN changes
                // during the outage are reacted to by the restarted
                // controller's reconstruction round.
                needs_round = None;
            }
            if let Some(trigger) = needs_round.take() {
                self.round(trigger);
            }
        }
        // Finalize.
        self.report.makespan = self.now;
        let st = self.engine.take_stats();
        self.report.lp_solves += st.lp_solves;
        self.report.lp_time_s += st.lp_time_s;
        self.report.round_time_s += st.round_time_s;
        self.report.gamma_cache_hits += st.gamma_cache_hits;
        self.report.component_solves += st.component_solves;
        self.report.component_reuses += st.component_reuses;
        self.report.shard_migrations += st.shard_migrations;
        self.report.floor_shortfall_gbps += st.floor_shortfall_gbps;
        self.report.clone()
    }

    /// Advance simulated time, draining FlowGroups and integrating
    /// utilization over the busy period. In belief mode the drain is
    /// throttled by ground truth: a coflow achieves
    /// `min(allocated, what its true edges admit)` — an over-optimistic
    /// belief cannot move bytes the real network will not carry.
    fn advance(&mut self, target: f64) {
        let dt = (target - self.now).max(0.0);
        if dt > 0.0 && !self.engine.is_empty() {
            let mut throttle = self.truth_throttle();
            if self.down {
                // Controller down: agents keep draining their last-known
                // allocation, scaled to the conservative degraded-mode
                // fair share (and still capped by ground truth).
                let scale = self
                    .cfg
                    .chaos
                    .as_ref()
                    .map(|c| c.degraded_scale)
                    .unwrap_or(1.0);
                let mut factors = throttle.take().unwrap_or_default();
                self.engine.visit_allocations(|cs, _| {
                    *factors.entry(cs.id).or_insert(1.0) *= scale;
                });
                throttle = Some(factors);
            }
            if let Some(site) = self.dead_site {
                // Ground truth: nothing moves for coflows touching the
                // failed site. Before detection they still hold their
                // allocations (the stall the watchdog measures); after
                // detection they are parked and no longer drain at all.
                let mut factors = throttle.take().unwrap_or_default();
                let mut touched = 0usize;
                self.engine.visit_allocations(|cs, _| {
                    if cs.groups.iter().any(|g| g.src == site || g.dst == site) {
                        factors.insert(cs.id, 0.0);
                        touched += 1;
                    }
                });
                if !self.site_detected {
                    self.report.agent_stall_s += touched as f64 * dt;
                }
                throttle = Some(factors);
            }
            if self.has_streams {
                // Violation-seconds: an admitted stream whose achieved
                // rate (allocation after truth throttling and degraded
                // scaling) sits below its floor on any unfinished group
                // accrues `dt`.
                let Simulation { engine, report, record_idx, .. } = &mut *self;
                engine.visit_allocations(|cs, rates| {
                    let Some(floor) = cs.rate_floor() else { return };
                    if !cs.admitted || cs.done() {
                        return;
                    }
                    let factor =
                        throttle.as_ref().and_then(|m| m.get(&cs.id)).copied().unwrap_or(1.0);
                    let violated = (0..cs.groups.len()).any(|gi| {
                        if cs.remaining[gi] <= 1e-9 {
                            return false;
                        }
                        let rate: f64 = rates
                            .and_then(|r| r.get(gi))
                            .map(|r| r.iter().sum())
                            .unwrap_or(0.0);
                        rate * factor < floor - 1e-9
                    });
                    if violated {
                        if let Some(&idx) = record_idx.get(&cs.id) {
                            report.coflows[idx].violation_s += dt;
                        }
                        report.stream_violation_s += dt;
                    }
                });
            }
            let moved = self.engine.drain_with(dt, 0.0, throttle.as_ref());
            self.report.transferred_gbit += moved;
            if self.down {
                self.report.drained_degraded_gbit += moved;
            }
            let cap = self
                .truth
                .as_ref()
                .map(|t| t.total_capacity())
                .unwrap_or_else(|| self.engine.wan().total_capacity());
            self.report.capacity_gbit += cap * dt;
        }
        self.now = target;
    }

    /// Per-coflow throttle factors against ground truth
    /// ([`RoundEngine::throttle_factors`] over the *true* capacities —
    /// the same per-coflow-min algorithm the engine's sub-ρ clamp uses
    /// over believed ones). `None` when truth admits the full allocation
    /// (the common case) or under the oracle.
    fn truth_throttle(&self) -> Option<HashMap<CoflowId, f64>> {
        let truth = self.truth.as_ref()?;
        // O(E) precheck before the O(active · paths · hops) usage scan:
        // feasibility keeps usage within *believed* capacities, so
        // throttling is only possible while some edge's truth sits below
        // its belief — which is false in the steady state (beliefs
        // converge) and on every loop step between truth changes.
        let possible = (0..truth.num_edges())
            .any(|e| truth.link(e).avail() < self.engine.wan().link(e).avail());
        if !possible {
            return None;
        }
        let factors = self.engine.throttle_factors(&truth.capacities());
        if factors.is_empty() {
            None
        } else {
            Some(factors)
        }
    }

    /// Apply a ground-truth WAN event in belief mode: structural events
    /// are observable (port state) and forward to the scheduler; bandwidth
    /// changes stay in the truth plane — the scheduler has to *discover*
    /// them — and open a staleness episode when truth drifts ≥ ρ from the
    /// believed capacity.
    fn on_truth_event(&mut self, ev: &LinkEvent) -> Option<RoundTrigger> {
        self.truth.as_mut().unwrap().apply_event(ev);
        match *ev {
            LinkEvent::Fail(..) | LinkEvent::Recover(..) => {
                let now = self.now;
                self.engine.handle_wan_event_at(ev, now).trigger()
            }
            LinkEvent::SetBandwidth(u, v, _) => {
                let truth = self.truth.as_ref().unwrap();
                if let Some(e) = truth.edge_between(u, v) {
                    let believed = self.engine.wan().link(e).avail();
                    let tru = truth.link(e).avail();
                    let dev = (tru - believed).abs() / believed.max(1e-9);
                    if dev >= self.cfg.rho {
                        if let std::collections::hash_map::Entry::Vacant(slot) =
                            self.pending_stale.entry(e)
                        {
                            slot.insert(self.now);
                            self.report.stale_events += 1;
                        }
                    } else if let Some(t0) = self.pending_stale.remove(&e) {
                        // Truth wandered back inside the band on its own:
                        // the episode ended without scheduler action.
                        self.report.stale_resolved += 1;
                        self.report.stale_reaction_s_sum += self.now - t0;
                    }
                }
                None
            }
        }
    }

    /// One telemetry sampling tick (belief mode): ingest per-edge achieved
    /// throughput (capped by the sender's own allocation — the censoring
    /// that makes estimation hard), probe stale edges, sample the
    /// estimation error, push belief changes through the engine's ρ gate,
    /// and settle staleness episodes the refreshed belief has closed.
    fn telemetry_tick(&mut self) -> Option<RoundTrigger> {
        let now = self.now;
        let rho = self.cfg.rho;
        let probe_after = self.cfg.telemetry.probe_after_s;
        let Simulation { truth, engine, report, pending_stale, .. } = self;
        let truth = truth.as_ref()?;
        let num_edges = truth.num_edges();
        let usage = engine.edge_usage(num_edges);
        for (e, &used) in usage.iter().enumerate() {
            let tl = truth.link(e);
            if !tl.up || used <= 1e-9 {
                continue;
            }
            let tru = tl.avail();
            let achieved = used.min(tru);
            let capped = used > tru + 1e-9;
            engine.observe_edge(e, achieved, capped, now);
            report.est_samples += 1;
        }
        if probe_after > 0.0 {
            for e in telemetry::stale_edges(engine.estimator(), engine.wan(), now, probe_after) {
                // A probe sees the true available capacity (burst past the
                // allocation cap); measurement noise is the estimator's
                // obs-noise model's job.
                engine.probe_edge(e, truth.link(e).avail(), now);
                report.est_probes += 1;
            }
        }
        // Estimation error of the capacity the scheduler actually consumes.
        for e in 0..num_edges {
            let tl = truth.link(e);
            if tl.up && tl.avail() > 1e-9 {
                let believed = engine.wan().link(e).avail();
                report.est_mape_sum += (believed - tl.avail()).abs() / tl.avail();
                report.est_mape_samples += 1;
            }
        }
        let trigger = engine.refresh_beliefs().and_then(|r| r.trigger());
        pending_stale.retain(|&e, t0| {
            let believed = engine.wan().link(e).avail();
            let tru = truth.link(e).avail();
            if (tru - believed).abs() / believed.max(1e-9) < rho {
                report.stale_resolved += 1;
                report.stale_reaction_s_sum += now - *t0;
                false
            } else {
                true
            }
        });
        trigger
    }

    /// True while some up edge's believed capacity is measurably away
    /// from ground truth — probing can still improve the schedule, so
    /// telemetry ticks stay worth their while.
    fn beliefs_diverge_from_truth(&self) -> bool {
        let Some(truth) = self.truth.as_ref() else { return false };
        (0..truth.num_edges()).any(|e| {
            let tl = truth.link(e);
            tl.up && {
                let believed = self.engine.wan().link(e).avail();
                (believed - tl.avail()).abs() > 1e-6 * tl.avail().max(1.0)
            }
        })
    }

    /// Apply an announced-maintenance capacity prior (window start or
    /// end); the belief jumps with zero discovery latency and stays
    /// pinned against samples/probes until the window closes.
    fn apply_prior(
        &mut self,
        u: usize,
        v: usize,
        gbps: Option<f64>,
        until: f64,
    ) -> Option<RoundTrigger> {
        let e = self.engine.wan().edge_between(u, v)?;
        let val = gbps.unwrap_or_else(|| self.engine.wan().link(e).base_capacity);
        let now = self.now;
        self.engine.announce_prior(e, val, now, until.max(now));
        let trigger = self.engine.refresh_beliefs().and_then(|r| r.trigger());
        // Settle any staleness episode the prior just closed (e.g. the
        // same-timestamp truth restore at a window's end was processed
        // before this prior): the announcement reacted at latency ~0.
        if let Some(truth) = self.truth.as_ref() {
            let believed = self.engine.wan().link(e).avail();
            let tru = truth.link(e).avail();
            if (tru - believed).abs() / believed.max(1e-9) < self.cfg.rho {
                if let Some(t0) = self.pending_stale.remove(&e) {
                    self.report.stale_resolved += 1;
                    self.report.stale_reaction_s_sum += now - t0;
                }
            }
        }
        trigger
    }

    /// Remove finished coflows; update job DAGs. Returns true if anything
    /// finished.
    fn process_completions(&mut self) -> bool {
        let finished = self.engine.take_finished();
        for id in &finished {
            let idx = self.record_idx[id];
            self.report.coflows[idx].finish = Some(self.now);
        }
        for id in &finished {
            if let Some(&(job, stage)) = self.owners.get(id) {
                self.complete_stage(job, stage);
            }
        }
        !finished.is_empty()
    }

    fn on_job_arrival(&mut self, j: usize) {
        let stages: Vec<usize> = (0..self.jobs[j].stages.len())
            .filter(|&s| self.jobs[j].stages[s].deps.is_empty())
            .collect();
        for s in stages {
            let t = self.now + self.jobs[j].stages[s].compute_s;
            self.push_event(t, EvKind::CoflowSubmit { job: j, stage: s });
        }
    }

    /// Submit stage (job, stage)'s coflow. Returns true if a schedulable
    /// coflow entered the system.
    fn on_coflow_submit(&mut self, job: usize, stage: usize) -> bool {
        let st = &self.jobs[job].stages[stage];
        let wan_flows = st.flows.iter().filter(|f| f.src_dc != f.dst_dc).count();
        if wan_flows == 0 {
            self.complete_stage(job, stage);
            return false;
        }
        let mut flows = st.flows.clone();
        let mut class = st.class.clone();
        let st_deadline = st.deadline;
        if let ServiceClass::MlSync { tree, .. } = &mut class {
            // Network-aware tree adaptation: each iteration re-arrives as
            // its own coflow, so reshaping is a per-submit decision against
            // the scheduler's *believed* WAN — a degraded tree link makes
            // the child bypass its parent and ship straight to the root
            // (the auxiliary route) for this iteration.
            let reshapes = reshape_degraded_tree(tree, &mut flows, self.engine.wan());
            self.report.tree_reshapes += reshapes;
        }
        let id = self.next_coflow_id;
        self.next_coflow_id += 1;
        let mut coflow = Coflow::new(id, flows).with_arrival(self.now).with_class(class);
        if let Some(d) = st_deadline {
            coflow = coflow.with_deadline(d);
        }
        let mut state = CoflowState::from_coflow(&coflow);
        // Coordination delay: the coflow is known to the controller but no
        // bandwidth flows until the next round after the delay elapses; we
        // model it as added arrival latency on the record.
        let min_cct = self.engine.standalone_min_cct(&state);

        let mut admitted = true;
        if state.deadline.is_some() || state.rate_floor().is_some() {
            admitted = self.engine.admit(self.now, &state);
        }
        state.admitted = admitted;
        if admitted && state.rate_floor().is_some() {
            self.has_streams = true;
        }

        // Offered-vs-admitted accounting + a backlog depth sample (the
        // submitted coflow counts itself when it will enter the engine).
        // Pure bookkeeping: no RNG draws, no event-queue effects — fixed
        // job-set runs stay bit-identical.
        self.report.offered += 1;
        if admitted {
            self.report.admitted += 1;
        } else {
            self.report.rejected += 1;
        }
        let depth = self.engine.len() + admitted as usize;
        self.report.backlog.push((self.now, depth));

        self.owners.insert(id, (job, stage));
        self.record_idx.insert(id, self.report.coflows.len());
        self.report.coflows.push(CoflowRecord {
            id,
            job: Some(self.jobs[job].id),
            arrival: self.now,
            finish: None,
            volume: state.total_remaining(),
            min_cct,
            deadline: state.deadline,
            admitted,
            class: state.class.name(),
            violation_s: 0.0,
        });
        if !admitted {
            // Rejected coflows fall back to the framework's default
            // transfer (§4.4); the stage completes after the standalone
            // minimum CCT without occupying Terra-scheduled bandwidth, and
            // the coflow counts as missing its deadline.
            let t = (self.now + min_cct.max(0.0)).min(self.cfg.max_time);
            self.push_event(t, EvKind::StageDone { job, stage });
            return false;
        }
        if self.cfg.coordination_delay_s > 0.0 {
            // Controller feedback loop: the coflow is recorded now (its CCT
            // clock is ticking) but receives bandwidth only after the
            // coordination delay — this is what penalizes sub-second
            // coflows under centralized scheduling (Fig 7d).
            let t = self.now + self.cfg.coordination_delay_s;
            self.push_event(t, EvKind::Activate(Box::new(state)));
            return false;
        }
        self.engine.insert(state);
        true
    }

    fn complete_stage(&mut self, job: usize, stage: usize) {
        if self.job_states[job].stage_done[stage] {
            return;
        }
        self.job_states[job].stage_done[stage] = true;
        let num_stages = self.jobs[job].stages.len();
        for s in 0..num_stages {
            if self.jobs[job].stages[s].deps.contains(&stage) {
                self.job_states[job].deps_remaining[s] -= 1;
                if self.job_states[job].deps_remaining[s] == 0 {
                    let t = self.now + self.jobs[job].stages[s].compute_s;
                    self.push_event(t, EvKind::CoflowSubmit { job, stage: s });
                }
            }
        }
        if self.job_states[job].stage_done.iter().all(|&d| d) {
            self.report.jobs[job].finish = Some(self.now);
        }
    }

    /// Run one scheduling round through the shared engine. Rounds reacting
    /// to WAN changes are timed separately: their wall-clock cost is the
    /// reaction latency the paper's failure case study reports (Fig 10).
    fn round(&mut self, trigger: RoundTrigger) {
        self.round_inner(trigger, trigger == RoundTrigger::WanChange);
    }

    /// Fraction of base capacity below which a believed tree link counts
    /// as degraded and triggers an MlSync aggregation-tree reshape.
    pub const TREE_RESHAPE_FRACTION: f64 = 0.5;

    fn round_inner(&mut self, trigger: RoundTrigger, count_reaction: bool) {
        let t0 = std::time::Instant::now();
        self.engine.round(self.now, trigger);
        self.report.rounds += 1;
        if self.pending_recovery {
            // First round of the restarted controller: reconstruction
            // from resync'd state back to a full allocation.
            self.report.recovery_round_s += t0.elapsed().as_secs_f64();
            self.pending_recovery = false;
        }
        if count_reaction {
            let dt = t0.elapsed().as_secs_f64();
            self.report.wan_rounds += 1;
            self.report.reaction_time_s += dt;
            self.report.max_reaction_s = self.report.max_reaction_s.max(dt);
        }
    }
}

/// Reshape an MlSync aggregation tree against the scheduler's believed
/// WAN: any non-root tree edge (child → parent) whose direct link is
/// missing, down, or believed below
/// [`Simulation::TREE_RESHAPE_FRACTION`] of base capacity is replaced by
/// an auxiliary child → root route, and the iteration's matching flows
/// move with it. Returns the number of re-parented edges.
fn reshape_degraded_tree(
    tree: &mut crate::coflow::AggTree,
    flows: &mut [crate::coflow::Flow],
    wan: &Wan,
) -> usize {
    let root = tree.root;
    let mut reshapes = 0;
    for (child, parent) in tree.edges.iter_mut() {
        if *parent == root || *child == root {
            continue;
        }
        let degraded = match wan.edge_between(*child, *parent) {
            None => true,
            Some(e) => {
                let l = wan.link(e);
                !l.up || l.avail() < Simulation::TREE_RESHAPE_FRACTION * l.base_capacity
            }
        };
        if !degraded {
            continue;
        }
        for f in flows.iter_mut() {
            if f.src_dc == *child && f.dst_dc == *parent {
                f.dst_dc = root;
            }
        }
        *parent = root;
        reshapes += 1;
    }
    reshapes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Flow, GB};
    use crate::net::topologies;
    use crate::scheduler::terra::{TerraConfig, TerraPolicy};

    fn mk_flow(id: u64, s: usize, d: usize, gb: f64) -> Flow {
        Flow { id, src_dc: s, dst_dc: d, volume: gb * GB }
    }

    fn terra0() -> Box<dyn Policy> {
        Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() }))
    }

    #[test]
    fn single_coflow_min_cct() {
        // 5 GB A->B on fig1a: 40 Gbit over 20 Gbps (two paths) = 2 s.
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let rep = sim.run_jobs(vec![job]);
        assert_eq!(rep.jobs.len(), 1);
        let jct = rep.jobs[0].jct().unwrap();
        assert!((jct - 2.0).abs() < 0.1, "jct={jct}");
        assert_eq!(rep.unfinished(), 0);
    }

    #[test]
    fn fig1_average_cct_near_optimal() {
        // Paper Fig 1f: joint solution averages 7.15 s for Coflow-1 (5 GB
        // A->B) and Coflow-2 (5 GB A->B + 25 GB C->B).
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let j1 = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let j2 = Job::map_reduce(
            2,
            0.0,
            0.0,
            vec![mk_flow(0, 0, 1, 5.0), mk_flow(1, 2, 1, 25.0)],
        );
        let rep = sim.run_jobs(vec![j1, j2]);
        let avg = rep.avg_cct();
        // Terra should beat flow fair sharing (14 s), multipath (10.6 s) and
        // coflow-only (12 s); optimum is 7.15 s.
        assert!(avg < 10.0, "avg CCT {avg}");
        assert!(avg > 6.9, "cannot beat the offline optimum: {avg}");
    }

    #[test]
    fn compute_time_adds_to_jct() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 5.0, 3.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let rep = sim.run_jobs(vec![job]);
        let jct = rep.jobs[0].jct().unwrap();
        assert!((jct - 5.0).abs() < 0.1, "jct={jct} (3 compute + 2 transfer)");
        // Coflow record arrival is after compute.
        assert!((rep.coflows[0].arrival - 8.0).abs() < 1e-6);
    }

    #[test]
    fn dag_dependencies_sequence() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        // Two-stage DAG: stage0 5 GB A->B (2 s), then stage1 5 GB B->C (2 s).
        let job = Job {
            id: 1,
            arrival: 0.0,
            stages: vec![
                Stage {
                    deps: vec![],
                    compute_s: 0.0,
                    flows: vec![mk_flow(0, 0, 1, 5.0)],
                    ..Default::default()
                },
                Stage {
                    deps: vec![0],
                    compute_s: 1.0,
                    flows: vec![mk_flow(0, 1, 2, 5.0)],
                    ..Default::default()
                },
            ],
        };
        let rep = sim.run_jobs(vec![job]);
        let jct = rep.jobs[0].jct().unwrap();
        assert!((jct - 5.0).abs() < 0.2, "jct={jct} (2 + 1 + 2)");
        assert_eq!(rep.coflows.len(), 2);
    }

    #[test]
    fn link_failure_triggers_reroute() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]); // 200 Gbit
        sim.add_job(job);
        // Direct A-B link fails at t=1; Terra must continue via C.
        sim.add_wan_event(1.0, LinkEvent::Fail(0, 1));
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0);
        let jct = rep.jobs[0].jct().unwrap();
        // 20 Gbps for 1 s, then 10 Gbps via C: 1 + 180/10 = 19 s.
        assert!((jct - 19.0).abs() < 0.5, "jct={jct}");
    }

    #[test]
    fn small_fluctuation_ignored_large_reacts() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]);
        sim.add_job(job);
        // 10% drop on A->B at t=1 (< rho): no re-optimization round.
        sim.add_wan_event(1.0, LinkEvent::SetBandwidth(0, 1, 9.0));
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0);
        // The clamp still keeps the allocation feasible; JCT grows slightly.
        let jct = rep.jobs[0].jct().unwrap();
        assert!(jct > 10.0 && jct < 12.0, "jct={jct}");
    }

    #[test]
    fn deadline_admission_and_completion() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(
            wan,
            Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() })),
            SimConfig::default(),
        );
        // Feasible deadline: min CCT 2 s, deadline 4 s.
        let mut j1 = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        j1.stages[0].deadline = Some(4.0);
        // Infeasible deadline: min CCT 10 s (25 GB on 20 Gbps), deadline 3 s.
        let mut j2 = Job::map_reduce(2, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]);
        j2.stages[0].deadline = Some(3.0);
        let rep = sim.run_jobs(vec![j1, j2]);
        let d1 = rep.coflows.iter().find(|c| c.job == Some(1)).unwrap();
        let d2 = rep.coflows.iter().find(|c| c.job == Some(2)).unwrap();
        assert!(d1.admitted && d1.met_deadline(), "{d1:?}");
        assert!(!d2.admitted && !d2.met_deadline(), "{d2:?}");
        // Rejected job still completes via fallback.
        assert!(rep.jobs[1].finish.is_some());
    }

    #[test]
    fn utilization_bounded() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let rep = sim.run_jobs(vec![job]);
        let u = rep.utilization();
        assert!(u > 0.0 && u <= 1.0, "utilization={u}");
        // 40 Gbit transferred.
        assert!((rep.transferred_gbit - 40.0).abs() < 1e-6);
    }

    #[test]
    fn partitioned_wan_starves_gracefully() {
        let mut wan = topologies::fig1a();
        wan.apply_event(&LinkEvent::Fail(0, 1));
        wan.apply_event(&LinkEvent::Fail(0, 2));
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let rep = sim.run_jobs(vec![job]);
        assert_eq!(rep.unfinished(), 1);
        assert!(rep.jobs[0].finish.is_none());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn rejects_nan_event_times() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        sim.add_wan_event(f64::NAN, LinkEvent::Fail(0, 1));
    }

    #[test]
    fn oracle_mode_runs_no_telemetry() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        sim.add_wan_event(1.0, LinkEvent::SetBandwidth(0, 1, 4.0));
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]);
        let rep = sim.run_jobs(vec![job]);
        assert_eq!(rep.unfinished(), 0);
        assert_eq!(rep.est_samples, 0);
        assert_eq!(rep.est_probes, 0);
        assert_eq!(rep.est_mape(), 0.0);
        // The oracle's staleness episodes resolve instantly.
        assert_eq!(rep.stale_events, 1);
        assert_eq!(rep.avg_stale_reaction_s(), 0.0);
    }

    /// The headline scenario estimation exists for: ground truth collapses
    /// a link the scheduler is using, the scheduler is NOT told, and it
    /// must discover the change from capped achieved-throughput samples —
    /// with a measurable (non-zero) reaction latency — then still finish
    /// the workload.
    #[test]
    fn belief_mode_discovers_withheld_capacity_drop() {
        let wan = topologies::fig1a();
        let cfg = SimConfig {
            telemetry: crate::net::TelemetryConfig {
                sample_interval_s: 0.25,
                probe_after_s: 2.0,
                ..crate::net::TelemetryConfig::by_name("ewma").unwrap()
            },
            ..Default::default()
        };
        let mut sim = Simulation::new(wan, terra0(), cfg);
        // 200 Gbit A->B; at t=1 the direct link truly drops to 2 Gbps.
        sim.add_job(Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]));
        sim.add_wan_event(1.0, LinkEvent::SetBandwidth(0, 1, 2.0));
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0, "workload must survive estimation");
        assert!(rep.est_samples > 0, "no passive samples ingested");
        assert_eq!(rep.stale_events, 1, "the withheld drop must open a staleness episode");
        assert_eq!(rep.stale_resolved, 1, "sampling must eventually discover the drop");
        assert!(
            rep.avg_stale_reaction_s() > 0.0,
            "estimated discovery cannot be instantaneous"
        );
        assert!(rep.est_mape() > 0.0, "estimation error must be visible in the metric");
        assert!(rep.wan_rounds > 0, "the discovered drop must have re-optimized");
        // Discovery is bounded: a few sampling intervals, not the horizon.
        assert!(
            rep.avg_stale_reaction_s() < 10.0,
            "took {}s to notice an 80% drop",
            rep.avg_stale_reaction_s()
        );
    }

    /// Belief-mode runs are deterministic: telemetry is driven entirely by
    /// the virtual clock and the seeded event stream.
    #[test]
    fn belief_mode_is_deterministic() {
        let run = || {
            let wan = topologies::fig1a();
            let cfg = SimConfig {
                telemetry: crate::net::TelemetryConfig::by_name("kalman").unwrap(),
                ..Default::default()
            };
            let mut sim = Simulation::new(wan, terra0(), cfg);
            sim.add_job(Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]));
            sim.add_wan_event(1.0, LinkEvent::SetBandwidth(0, 1, 3.0));
            sim.add_wan_event(4.0, LinkEvent::SetBandwidth(0, 1, 9.0));
            sim.run()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.est_samples, b.est_samples);
        assert_eq!(a.est_mape_sum.to_bits(), b.est_mape_sum.to_bits());
    }

    #[test]
    fn repeat_rounds_hit_gamma_cache() {
        // Several same-pair coflows arriving over time: every arrival round
        // after the first should reuse cached Γ for already-active coflows.
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let jobs: Vec<Job> = (0..4)
            .map(|i| {
                Job::map_reduce(i + 1, i as f64 * 0.5, 0.0, vec![mk_flow(0, 0, 1, 25.0)])
            })
            .collect();
        let rep = sim.run_jobs(jobs);
        assert_eq!(rep.unfinished(), 0);
        assert!(rep.gamma_cache_hits > 0, "no Γ-cache hits recorded");
    }

    /// `chaos: None` is inert: runs are deterministic and every chaos
    /// metric stays at its zero default (the always-up path emits none).
    #[test]
    fn chaos_none_is_inert_and_deterministic() {
        let run = || {
            let wan = topologies::fig1a();
            let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
            sim.add_job(Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]));
            sim.add_wan_event(1.0, LinkEvent::SetBandwidth(0, 1, 9.0));
            sim.run()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.chaos_kills, 0);
        assert_eq!(a.chaos_downtime_s, 0.0);
        assert_eq!(a.drained_degraded_gbit, 0.0);
        assert_eq!(a.recovery_round_s, 0.0);
        assert_eq!(a.preserved_fraction(), 1.0);
    }

    /// The headline recovery comparison: resync reconstruction preserves
    /// every achieved byte across the crash, the from-zero strawman throws
    /// them away, and CCTs order accordingly
    /// (always-up ≤ resync < from-zero).
    #[test]
    fn resync_preserves_progress_from_zero_does_not() {
        // 200 Gbit A->B over 20 Gbps: 10 s always-up. Kill at t=2 (40 Gbit
        // done, 160 in flight), restart at t=4 (20 more Gbit drained at the
        // 0.5-degraded rate).
        let run = |chaos: Option<ChaosConfig>| {
            let wan = topologies::fig1a();
            let cfg = SimConfig { chaos, ..Default::default() };
            let mut sim = Simulation::new(wan, terra0(), cfg);
            sim.add_job(Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]));
            sim.run()
        };
        let up = run(None);
        let resync = run(Some(ChaosConfig::new(2.0, 4.0, RecoveryMode::Resync)));
        let zero = run(Some(ChaosConfig::new(2.0, 4.0, RecoveryMode::FromZero)));
        assert_eq!(up.unfinished(), 0);
        assert_eq!(resync.unfinished(), 0);
        assert_eq!(zero.unfinished(), 0);

        assert_eq!(resync.chaos_kills, 1);
        assert!((resync.chaos_downtime_s - 2.0).abs() < 1e-9);
        assert!(
            resync.drained_degraded_gbit > 1.0,
            "degraded agents must keep draining: {}",
            resync.drained_degraded_gbit
        );
        // Resync keeps (indeed shrinks, via degraded drains) the in-flight
        // volume across the restart.
        assert!(
            (resync.preserved_fraction() - 1.0).abs() < 1e-9,
            "pf={}",
            resync.preserved_fraction()
        );
        // From-zero re-inflates 160 in-flight Gbit back to the full 200:
        // preserved fraction 0.8.
        let pf = zero.preserved_fraction();
        assert!(pf > 0.7 && pf < 0.9, "pf={pf}");
        assert!(resync.recovery_round_s > 0.0, "recovery round must be timed");

        let (u, r, z) = (up.avg_cct(), resync.avg_cct(), zero.avg_cct());
        assert!(u <= r + 1e-6, "always-up {u} must not lose to chaos {r}");
        assert!(r < z, "resync {r} must beat from-zero {z}");
    }

    /// Submissions landing while the controller is down defer to the
    /// restart — the controller only learns of the coflow once it is back.
    #[test]
    fn submissions_defer_while_controller_down() {
        let wan = topologies::fig1a();
        let cfg = SimConfig {
            chaos: Some(ChaosConfig::new(1.0, 3.0, RecoveryMode::Resync)),
            ..Default::default()
        };
        let mut sim = Simulation::new(wan, terra0(), cfg);
        // Client submits at t=2, mid-outage.
        sim.add_job(Job::map_reduce(1, 2.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]));
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0);
        assert!(
            (rep.coflows[0].arrival - 3.0).abs() < 1e-6,
            "controller-side arrival must be at the restart: {}",
            rep.coflows[0].arrival
        );
        // 1 s waiting out the outage + 2 s transfer.
        let jct = rep.jobs[0].jct().unwrap();
        assert!((jct - 3.0).abs() < 0.1, "jct={jct}");
    }

    /// Chaos composes with belief mode: the crash wipes capacity beliefs
    /// (crash_reset), telemetry re-derives them after the restart, and the
    /// workload still finishes.
    #[test]
    fn chaos_with_belief_estimation_completes() {
        let wan = topologies::fig1a();
        let cfg = SimConfig {
            telemetry: crate::net::TelemetryConfig {
                sample_interval_s: 0.25,
                probe_after_s: 2.0,
                ..crate::net::TelemetryConfig::by_name("ewma").unwrap()
            },
            chaos: Some(ChaosConfig::new(2.0, 3.0, RecoveryMode::Resync)),
            ..Default::default()
        };
        let mut sim = Simulation::new(wan, terra0(), cfg);
        sim.add_job(Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]));
        sim.add_wan_event(1.0, LinkEvent::SetBandwidth(0, 1, 5.0));
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0);
        assert_eq!(rep.chaos_kills, 1);
        assert!(rep.est_samples > 0);
        assert!((rep.preserved_fraction() - 1.0).abs() < 1e-9);
    }

    /// Data-plane chaos: an agent kill stalls its traffic (undetected,
    /// allocated-but-idle), the detector parks the touched coflow with its
    /// progress preserved, and the heal resumes it from the remaining
    /// volume — not from zero.
    #[test]
    fn agent_chaos_parks_preserves_and_resumes() {
        // 200 Gbit A->B over 20 Gbps (two paths): 10 s always-up. Site B
        // dies at t=2 (40 Gbit done), detected at t=3, heals at t=6; the
        // remaining 160 Gbit takes 8 s more -> makespan ~14 s. A re-run
        // from zero would land at 16 s.
        let wan = topologies::fig1a();
        let cfg = SimConfig {
            chaos: Some(
                ChaosConfig::new(2.0, 6.0, RecoveryMode::Resync)
                    .with_target(ChaosTarget::Agent { site: 1 })
                    .with_detection_s(1.0),
            ),
            ..Default::default()
        };
        let mut sim = Simulation::new(wan, terra0(), cfg);
        sim.add_job(Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]));
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0);
        assert_eq!(rep.chaos_kills, 0, "the controller never died");
        assert_eq!(rep.agent_downs, 1);
        assert!((rep.agent_detection_s - 1.0).abs() < 1e-9, "{}", rep.agent_detection_s);
        assert_eq!(rep.agent_parked, 1);
        // One coflow stalled for the 1 s detection window.
        assert!((rep.agent_stall_s - 1.0).abs() < 1e-6, "{}", rep.agent_stall_s);
        assert!(
            (rep.makespan - 14.0).abs() < 0.3,
            "progress across the outage must be preserved: makespan {}",
            rep.makespan
        );
    }

    /// Coflows whose paths never touch the failed site are uninterrupted:
    /// on a line WAN (0-1-2-3) a partition of site 0 parks only the 0→1
    /// victim, and the 2→3 survivor's JCT is unchanged to the tolerance of
    /// the clock. (Survivors sharing links with the dead site may shift
    /// either way — they lose relay paths but inherit the victim's share —
    /// so the clean uninterrupted claim needs disjoint paths.)
    #[test]
    fn agent_chaos_survivors_uninterrupted() {
        let line = || {
            let mut w = Wan::new();
            let n: Vec<usize> = (0..4).map(|i| w.add_node(&format!("n{i}"), 0.0, i as f64)).collect();
            for i in 0..3 {
                w.add_link(n[i], n[i + 1], 10.0, None);
            }
            w
        };
        let run = |chaos: Option<ChaosConfig>| {
            let cfg = SimConfig { chaos, ..Default::default() };
            let mut sim = Simulation::new(line(), terra0(), cfg);
            sim.add_job(Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 12.5)]));
            sim.add_job(Job::map_reduce(2, 0.0, 0.0, vec![mk_flow(1, 2, 3, 5.0)]));
            sim.run()
        };
        let up = run(None);
        let chaos = run(Some(
            ChaosConfig::new(2.0, 6.0, RecoveryMode::Resync)
                .with_target(ChaosTarget::Partition { site: 0 })
                .with_detection_s(1.0),
        ));
        assert_eq!(up.unfinished(), 0);
        assert_eq!(chaos.unfinished(), 0);
        assert_eq!(chaos.agent_downs, 1);
        assert_eq!(chaos.agent_parked, 1, "only the coflow touching site 0 parks");
        let (u, c) = (up.jobs[1].jct().unwrap(), chaos.jobs[1].jct().unwrap());
        assert!((c - u).abs() < 1e-9, "survivor perturbed by the failure: {c} vs {u}");
        // The victim cannot finish before the heal.
        assert!(chaos.jobs[0].jct().unwrap() > 6.0);
    }

    /// An agent-chaos cycle that never fires inside the horizon is inert:
    /// bit-identical to the no-chaos run, zero agent metrics.
    #[test]
    fn agent_chaos_beyond_horizon_is_inert() {
        let run = |chaos: Option<ChaosConfig>| {
            let wan = topologies::fig1a();
            let cfg = SimConfig { chaos, ..Default::default() };
            let mut sim = Simulation::new(wan, terra0(), cfg);
            sim.add_job(Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]));
            sim.run()
        };
        let base = run(None);
        let late = run(Some(
            ChaosConfig::new(1000.0, 1001.0, RecoveryMode::Resync)
                .with_target(ChaosTarget::Agent { site: 1 }),
        ));
        assert_eq!(base.makespan.to_bits(), late.makespan.to_bits());
        assert_eq!(base.rounds, late.rounds);
        assert_eq!(late.agent_downs, 0);
        assert_eq!(late.agent_detection_s, 0.0);
        assert_eq!(late.agent_parked, 0);
        assert_eq!(late.agent_stall_s, 0.0);
    }

    /// A stream with a feasible floor accrues no violation-seconds while
    /// capacity lasts; once the WAN collapses below the floor, every
    /// simulated second below the floor books as a violation and the
    /// round-level shortfall surfaces in the report.
    #[test]
    fn stream_violation_seconds_accrue_under_collapse() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let mut job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 2.5)]); // 20 Gbit
        job.stages[0].class = ServiceClass::Stream { rate_floor_gbps: 4.0 };
        sim.add_job(job);
        // Both 0→1 paths collapse to 1 Gbps at t=0.5: 2 Gbps total < 4.
        sim.add_wan_event(0.5, LinkEvent::SetBandwidth(0, 1, 1.0));
        sim.add_wan_event(0.5, LinkEvent::SetBandwidth(0, 2, 1.0));
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0);
        let rec = &rep.coflows[0];
        assert_eq!(rec.class, "stream");
        assert!(rec.admitted, "feasible floor must admit");
        assert!(
            rep.stream_violation_s > 2.0,
            "collapse below the floor must accrue violation-seconds: {}",
            rep.stream_violation_s
        );
        assert!((rec.violation_s - rep.stream_violation_s).abs() < 1e-9);
        assert!(
            rep.floor_shortfall_gbps > 0.0,
            "infeasible floors must surface as round-level shortfall"
        );
    }

    /// A stream alone on a healthy WAN: floor honored throughout, zero
    /// violation-seconds, zero shortfall.
    #[test]
    fn stream_with_headroom_has_no_violations() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let mut job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        job.stages[0].class = ServiceClass::Stream { rate_floor_gbps: 4.0 };
        let rep = sim.run_jobs(vec![job]);
        assert_eq!(rep.unfinished(), 0);
        assert_eq!(rep.stream_violation_s, 0.0);
        assert_eq!(rep.floor_shortfall_gbps, 0.0);
        assert_eq!(rep.class_count("stream"), 1);
    }

    /// MlSync iterations re-arrive as separate coflows and reshape their
    /// aggregation tree when a tree link degrades: after 0→2 collapses,
    /// the second iteration routes node 0's update straight to the root.
    #[test]
    fn mlsync_reshapes_tree_on_degraded_link() {
        use crate::coflow::AggTree;
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let tree = AggTree { root: 1, edges: vec![(0, 2), (2, 1)] };
        let iter_flows =
            vec![mk_flow(0, 0, 2, 1.0), mk_flow(1, 2, 1, 1.0)]; // 8 Gbit per edge
        let mk_stage = |deps: Vec<usize>| Stage {
            deps,
            compute_s: 2.0,
            flows: iter_flows.clone(),
            deadline: None,
            class: ServiceClass::MlSync { tree: tree.clone(), iteration_gbit: 8.0 },
        };
        let job = Job { id: 1, arrival: 0.0, stages: vec![mk_stage(vec![]), mk_stage(vec![0])] };
        sim.add_job(job);
        // Tree link 0→2 degrades to 2 Gbps (< half of base 10) between
        // iteration 1 (done ~2.4 s) and iteration 2's submit (~4.4 s);
        // iteration 2 must re-parent node 0 straight to the root.
        sim.add_wan_event(2.5, LinkEvent::SetBandwidth(0, 2, 2.0));
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0);
        assert_eq!(rep.class_count("ml-sync"), 2, "one coflow per iteration");
        assert_eq!(rep.tree_reshapes, 1, "exactly the degraded edge reshapes");
        assert!(rep.avg_iteration_s() > 0.0);
    }

    /// Chaos on the sharded control plane: the restarted controller
    /// re-admits in arrival order and still finishes everything.
    #[test]
    fn chaos_on_sharded_control_plane_completes() {
        let wan = topologies::fig1a();
        let cfg = SimConfig {
            shards: 2,
            chaos: Some(ChaosConfig::new(1.0, 2.0, RecoveryMode::Resync)),
            ..Default::default()
        };
        let mut sim = Simulation::new(wan, terra0(), cfg);
        let jobs: Vec<Job> = (0..4)
            .map(|i| {
                Job::map_reduce(i + 1, i as f64 * 0.25, 0.0, vec![mk_flow(0, 0, 1, 10.0)])
            })
            .collect();
        let rep = sim.run_jobs(jobs);
        assert_eq!(rep.unfinished(), 0);
        assert_eq!(rep.chaos_kills, 1);
        assert!((rep.preserved_fraction() - 1.0).abs() < 1e-9);
    }
}
