//! GDA jobs as DAGs of computation stages with coflows in between (§2.1,
//! §3.2). A stage starts when all its dependencies finish, computes for
//! `compute_s` seconds, then submits its shuffle coflow; the stage finishes
//! when the coflow completes. Job completion time (JCT) is the last stage's
//! finish minus the job's arrival — `JCT = Σ (T_comm + T_comp)` along the
//! DAG's critical path (§6.7, Fig 14).

use crate::coflow::{Flow, ServiceClass};

/// One computation stage plus its outgoing shuffle.
#[derive(Clone, Debug, Default)]
pub struct Stage {
    /// Indices of stages that must finish before this one starts.
    pub deps: Vec<usize>,
    /// Computation time (seconds) before the shuffle is submitted.
    pub compute_s: f64,
    /// The stage's WAN shuffle; empty for stages with no WAN transfer
    /// (e.g. final aggregation inside one datacenter).
    pub flows: Vec<Flow>,
    /// Optional relative deadline for the stage's coflow.
    pub deadline: Option<f64>,
    /// Service class of the stage's coflow ([`ServiceClass::Batch`] by
    /// default — the classic GDA shuffle).
    pub class: ServiceClass,
}

/// A GDA job: a DAG of stages.
#[derive(Clone, Debug, Default)]
pub struct Job {
    pub id: u64,
    pub arrival: f64,
    pub stages: Vec<Stage>,
}

impl Job {
    /// Total WAN volume of the job in Gbit.
    pub fn total_volume(&self) -> f64 {
        self.stages
            .iter()
            .flat_map(|s| s.flows.iter())
            .filter(|f| f.src_dc != f.dst_dc)
            .map(|f| f.volume)
            .sum()
    }

    /// Number of coflows (stages with at least one WAN flow).
    pub fn num_coflows(&self) -> usize {
        self.stages.iter().filter(|s| s.flows.iter().any(|f| f.src_dc != f.dst_dc)).count()
    }

    /// Validate the DAG: deps in range and acyclic (stages must be listed in
    /// a valid topological order: deps point backwards).
    pub fn validate(&self) -> Result<(), String> {
        for (i, s) in self.stages.iter().enumerate() {
            for &d in &s.deps {
                if d >= i {
                    return Err(format!("stage {i} depends on later/self stage {d}"));
                }
            }
        }
        Ok(())
    }

    /// Single-stage MapReduce-style job.
    pub fn map_reduce(id: u64, arrival: f64, compute_s: f64, flows: Vec<Flow>) -> Job {
        Job {
            id,
            arrival,
            stages: vec![Stage { deps: vec![], compute_s, flows, ..Default::default() }],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_topological_deps() {
        let mut j = Job::default();
        j.stages.push(Stage::default());
        j.stages.push(Stage { deps: vec![0], ..Default::default() });
        assert!(j.validate().is_ok());
        j.stages.push(Stage { deps: vec![3], ..Default::default() });
        assert!(j.validate().is_err());
    }

    #[test]
    fn volume_counts_wan_only() {
        let j = Job::map_reduce(
            1,
            0.0,
            5.0,
            vec![
                Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 4.0 },
                Flow { id: 1, src_dc: 1, dst_dc: 1, volume: 9.0 },
            ],
        );
        assert!((j.total_volume() - 4.0).abs() < 1e-12);
        assert_eq!(j.num_coflows(), 1);
    }
}
