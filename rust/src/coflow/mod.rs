//! The coflow abstraction (§2.3) and the FlowGroup scale-down (§3.1.1).
//!
//! A coflow is a collection of flows with a shared fate: the consuming
//! computation stage starts only after *all* flows finish. Lemma 3.1 lets
//! Terra coalesce all flows of a coflow sharing the same
//! `<src_datacenter, dst_datacenter>` pair into one **FlowGroup** whose
//! volume is the sum — any work-conserving intra-group schedule preserves
//! the group completion time — shrinking the optimization problem by orders
//! of magnitude.
//!
//! Units: volumes in **Gbit**, rates in **Gbps**, times in **seconds**.

use crate::net::NodeId;
use std::collections::BTreeMap;

/// Unique coflow identifier handed back by `submit_coflow` (§5.2).
pub type CoflowId = u64;

/// Gigabytes to Gbit.
pub const GB: f64 = 8.0;
/// Megabytes to Gbit.
pub const MB: f64 = 8.0 / 1024.0;

/// One application-level flow (e.g. a mapper-to-reducer shuffle transfer).
#[derive(Clone, Debug, PartialEq)]
pub struct Flow {
    /// Unique within the owning coflow (the Terra API requires flows to be
    /// uniquely identifiable for `update_coflow`, §5.2).
    pub id: u64,
    pub src_dc: NodeId,
    pub dst_dc: NodeId,
    /// Volume in Gbit.
    pub volume: f64,
}

/// All flows of one coflow between the same datacenter pair, coalesced
/// (Lemma 3.1). The optimizer only ever sees FlowGroups.
#[derive(Clone, Debug, PartialEq)]
pub struct FlowGroup {
    pub src: NodeId,
    pub dst: NodeId,
    /// Total volume in Gbit.
    pub volume: f64,
    /// Number of constituent flows (for reporting / Rapier comparison).
    pub num_flows: usize,
}

/// A coflow as submitted through the Terra API.
#[derive(Clone, Debug, Default)]
pub struct Coflow {
    pub id: CoflowId,
    /// Submission time (seconds since simulation/controller start).
    pub arrival: f64,
    /// Optional relative deadline `D_i` in seconds (§3.2).
    pub deadline: Option<f64>,
    pub flows: Vec<Flow>,
}

impl Coflow {
    pub fn new(id: CoflowId, flows: Vec<Flow>) -> Coflow {
        Coflow { id, arrival: 0.0, deadline: None, flows }
    }

    pub fn with_deadline(mut self, d: f64) -> Coflow {
        self.deadline = Some(d);
        self
    }

    pub fn with_arrival(mut self, t: f64) -> Coflow {
        self.arrival = t;
        self
    }

    /// Total bytes across all flows, in Gbit.
    pub fn total_volume(&self) -> f64 {
        self.flows.iter().map(|f| f.volume).sum()
    }

    /// Coalesce flows into FlowGroups keyed by `<src_dc, dst_dc>`
    /// (Lemma 3.1). Flows whose endpoints are in the same datacenter do not
    /// cross the WAN and are dropped (the paper only schedules WAN traffic).
    pub fn flow_groups(&self) -> Vec<FlowGroup> {
        coalesce(&self.flows)
    }

    /// Scale-down ratio achieved by FlowGroup coalescing:
    /// `|FlowGroups| / |Flows|` (§3.1.1; Figure 4 shows 16n flows -> 2).
    pub fn scale_down(&self) -> f64 {
        let wan_flows = self.flows.iter().filter(|f| f.src_dc != f.dst_dc).count();
        if wan_flows == 0 {
            return 1.0;
        }
        self.flow_groups().len() as f64 / wan_flows as f64
    }
}

/// Coalesce a flow list into FlowGroups (Lemma 3.1).
pub fn coalesce(flows: &[Flow]) -> Vec<FlowGroup> {
    let mut groups: BTreeMap<(NodeId, NodeId), (f64, usize)> = BTreeMap::new();
    for f in flows {
        if f.src_dc == f.dst_dc || f.volume <= 0.0 {
            continue;
        }
        let e = groups.entry((f.src_dc, f.dst_dc)).or_insert((0.0, 0));
        e.0 += f.volume;
        e.1 += 1;
    }
    groups
        .into_iter()
        .map(|((src, dst), (volume, num_flows))| FlowGroup { src, dst, volume, num_flows })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flow(id: u64, s: NodeId, d: NodeId, v: f64) -> Flow {
        Flow { id, src_dc: s, dst_dc: d, volume: v }
    }

    #[test]
    fn coalesce_groups_by_pair() {
        // Figure 4a: 5n maps in B(1), 3n maps in C(2), 2 reducers in A(0).
        // All 16n flows collapse into exactly 2 FlowGroups (B->A, C->A).
        let n = 4;
        let mut flows = Vec::new();
        let mut id = 0;
        for _ in 0..5 * n {
            for _ in 0..2 {
                flows.push(flow(id, 1, 0, 1.0 * GB));
                id += 1;
            }
        }
        for _ in 0..3 * n {
            for _ in 0..2 {
                flows.push(flow(id, 2, 0, 1.0 * GB));
                id += 1;
            }
        }
        assert_eq!(flows.len(), 16 * n);
        let groups = coalesce(&flows);
        assert_eq!(groups.len(), 2);
        let ba = groups.iter().find(|g| g.src == 1).unwrap();
        assert_eq!(ba.num_flows, 10 * n);
        assert!((ba.volume - 10.0 * n as f64 * GB).abs() < 1e-9);
    }

    #[test]
    fn coalesce_drops_intra_dc_and_empty() {
        let flows =
            vec![flow(0, 1, 1, 5.0), flow(1, 1, 2, 0.0), flow(2, 1, 2, 3.0), flow(3, 2, 1, 4.0)];
        let groups = coalesce(&flows);
        assert_eq!(groups.len(), 2);
        assert!(groups.iter().all(|g| g.volume > 0.0 && g.src != g.dst));
    }

    #[test]
    fn scale_down_matches_fig4() {
        let n = 10;
        let mut flows = Vec::new();
        for i in 0..16 * n {
            let src = if i < 10 * n { 1 } else { 2 };
            flows.push(flow(i as u64, src, 0, 1.0));
        }
        let c = Coflow::new(1, flows);
        assert!((c.scale_down() - 2.0 / (16.0 * n as f64)).abs() < 1e-12);
    }

    #[test]
    fn total_volume_sums() {
        let c = Coflow::new(1, vec![flow(0, 0, 1, 2.0), flow(1, 1, 0, 3.0)]);
        assert!((c.total_volume() - 5.0).abs() < 1e-12);
    }
}
