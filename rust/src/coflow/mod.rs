//! The coflow abstraction (§2.3) and the FlowGroup scale-down (§3.1.1).
//!
//! A coflow is a collection of flows with a shared fate: the consuming
//! computation stage starts only after *all* flows finish. Lemma 3.1 lets
//! Terra coalesce all flows of a coflow sharing the same
//! `<src_datacenter, dst_datacenter>` pair into one **FlowGroup** whose
//! volume is the sum — any work-conserving intra-group schedule preserves
//! the group completion time — shrinking the optimization problem by orders
//! of magnitude.
//!
//! Units: volumes in **Gbit**, rates in **Gbps**, times in **seconds**.

use crate::net::NodeId;
use std::collections::BTreeMap;

/// Unique coflow identifier handed back by `submit_coflow` (§5.2).
pub type CoflowId = u64;

/// A geo-ML aggregation tree (Li et al., PAPERS.md): each participating
/// datacenter pushes its gradient shard to a parent, up to the root. One
/// synchronization iteration is a coflow with one flow per tree edge.
#[derive(Clone, Debug, PartialEq)]
pub struct AggTree {
    /// The aggregating root datacenter.
    pub root: NodeId,
    /// `(child, parent)` directed edges; every participant except the root
    /// appears exactly once as a child.
    pub edges: Vec<(NodeId, NodeId)>,
}

impl AggTree {
    /// All participating datacenters (root + children), deduplicated, in
    /// ascending order.
    pub fn participants(&self) -> Vec<NodeId> {
        let mut p: Vec<NodeId> = std::iter::once(self.root)
            .chain(self.edges.iter().flat_map(|&(c, pa)| [c, pa]))
            .collect();
        p.sort_unstable();
        p.dedup();
        p
    }
}

/// The traffic class a coflow belongs to. The scheduler was built for
/// `Batch` (finite volume, minimize CCT); the other classes change what
/// admission, ordering, and filling optimize for:
///
/// - `Deadline`: batch semantics plus the §3.2 admission/dilation machinery
///   (tagged automatically when a deadline is set).
/// - `Stream`: a long-running analytics coflow with a minimum-rate
///   requirement (Aljoby et al.) — its floor is reserved *before* batch
///   max-min filling, it never enters Γ/SRTF ordering, and the metric that
///   matters is violation-seconds, not CCT. The floor applies to **each**
///   of the coflow's FlowGroups (generators emit single-group streams).
/// - `MlSync`: one iteration of geo-distributed ML synchronization over an
///   aggregation tree (Li et al.) — recurring, finite, CCT ≡ iteration
///   time; the tree can be reshaped between iterations when a link
///   degrades.
///
/// `Batch` is the **structural default**: every constructor that does not
/// explicitly set a class produces `Batch`, so class-free configurations
/// are bit-identical to the pre-class scheduler (golden-pinned).
#[derive(Clone, Debug, PartialEq, Default)]
pub enum ServiceClass {
    #[default]
    Batch,
    Deadline,
    Stream {
        /// Minimum sustained rate (Gbps) required per FlowGroup.
        rate_floor_gbps: f64,
    },
    MlSync {
        /// The aggregation tree this iteration's flows follow.
        tree: AggTree,
        /// Gradient-shard volume pushed over each tree edge per iteration,
        /// in Gbit.
        iteration_gbit: f64,
    },
}

impl ServiceClass {
    /// Stable short name used in reports, wire messages, and sweep rows.
    pub fn name(&self) -> &'static str {
        match self {
            ServiceClass::Batch => "batch",
            ServiceClass::Deadline => "deadline",
            ServiceClass::Stream { .. } => "stream",
            ServiceClass::MlSync { .. } => "ml-sync",
        }
    }

    /// The per-FlowGroup minimum-rate requirement, if this class has one.
    pub fn rate_floor(&self) -> Option<f64> {
        match self {
            ServiceClass::Stream { rate_floor_gbps } if *rate_floor_gbps > 0.0 => {
                Some(*rate_floor_gbps)
            }
            _ => None,
        }
    }
}

/// Gigabytes to Gbit.
pub const GB: f64 = 8.0;
/// Megabytes to Gbit.
pub const MB: f64 = 8.0 / 1024.0;

/// One application-level flow (e.g. a mapper-to-reducer shuffle transfer).
#[derive(Clone, Debug, PartialEq)]
pub struct Flow {
    /// Unique within the owning coflow (the Terra API requires flows to be
    /// uniquely identifiable for `update_coflow`, §5.2).
    pub id: u64,
    pub src_dc: NodeId,
    pub dst_dc: NodeId,
    /// Volume in Gbit.
    pub volume: f64,
}

/// All flows of one coflow between the same datacenter pair, coalesced
/// (Lemma 3.1). The optimizer only ever sees FlowGroups.
#[derive(Clone, Debug, PartialEq)]
pub struct FlowGroup {
    pub src: NodeId,
    pub dst: NodeId,
    /// Total volume in Gbit.
    pub volume: f64,
    /// Number of constituent flows (for reporting / Rapier comparison).
    pub num_flows: usize,
}

/// A coflow as submitted through the Terra API.
#[derive(Clone, Debug, Default)]
pub struct Coflow {
    pub id: CoflowId,
    /// Submission time (seconds since simulation/controller start).
    pub arrival: f64,
    /// Optional relative deadline `D_i` in seconds (§3.2).
    pub deadline: Option<f64>,
    /// Traffic class ([`ServiceClass::Batch`] unless set explicitly).
    pub class: ServiceClass,
    pub flows: Vec<Flow>,
}

impl Coflow {
    pub fn new(id: CoflowId, flows: Vec<Flow>) -> Coflow {
        Coflow { id, arrival: 0.0, deadline: None, class: ServiceClass::Batch, flows }
    }

    /// Set a relative deadline. A non-positive or non-finite `d` is
    /// **rejected** (logged, left as "no deadline") — propagating it would
    /// poison Γ-ordering and the §3.2 admission arithmetic downstream.
    pub fn with_deadline(mut self, d: f64) -> Coflow {
        if !d.is_finite() || d <= 0.0 {
            log::warn!("coflow {}: ignoring invalid deadline {d} (must be finite and > 0)", self.id);
            self.deadline = None;
            return self;
        }
        self.deadline = Some(d);
        self
    }

    pub fn with_class(mut self, class: ServiceClass) -> Coflow {
        self.class = class;
        self
    }

    pub fn with_arrival(mut self, t: f64) -> Coflow {
        self.arrival = t;
        self
    }

    /// Total bytes across all flows, in Gbit.
    pub fn total_volume(&self) -> f64 {
        self.flows.iter().map(|f| f.volume).sum()
    }

    /// Coalesce flows into FlowGroups keyed by `<src_dc, dst_dc>`
    /// (Lemma 3.1). Flows whose endpoints are in the same datacenter do not
    /// cross the WAN and are dropped (the paper only schedules WAN traffic).
    pub fn flow_groups(&self) -> Vec<FlowGroup> {
        coalesce(&self.flows)
    }

    /// Scale-down ratio achieved by FlowGroup coalescing:
    /// `|FlowGroups| / |Flows|` (§3.1.1; Figure 4 shows 16n flows -> 2).
    pub fn scale_down(&self) -> f64 {
        let wan_flows = self.flows.iter().filter(|f| f.src_dc != f.dst_dc).count();
        if wan_flows == 0 {
            return 1.0;
        }
        self.flow_groups().len() as f64 / wan_flows as f64
    }
}

/// Coalesce a flow list into FlowGroups (Lemma 3.1).
pub fn coalesce(flows: &[Flow]) -> Vec<FlowGroup> {
    let mut groups: BTreeMap<(NodeId, NodeId), (f64, usize)> = BTreeMap::new();
    for f in flows {
        if f.src_dc == f.dst_dc || f.volume <= 0.0 {
            continue;
        }
        let e = groups.entry((f.src_dc, f.dst_dc)).or_insert((0.0, 0));
        e.0 += f.volume;
        e.1 += 1;
    }
    groups
        .into_iter()
        .map(|((src, dst), (volume, num_flows))| FlowGroup { src, dst, volume, num_flows })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flow(id: u64, s: NodeId, d: NodeId, v: f64) -> Flow {
        Flow { id, src_dc: s, dst_dc: d, volume: v }
    }

    #[test]
    fn coalesce_groups_by_pair() {
        // Figure 4a: 5n maps in B(1), 3n maps in C(2), 2 reducers in A(0).
        // All 16n flows collapse into exactly 2 FlowGroups (B->A, C->A).
        let n = 4;
        let mut flows = Vec::new();
        let mut id = 0;
        for _ in 0..5 * n {
            for _ in 0..2 {
                flows.push(flow(id, 1, 0, 1.0 * GB));
                id += 1;
            }
        }
        for _ in 0..3 * n {
            for _ in 0..2 {
                flows.push(flow(id, 2, 0, 1.0 * GB));
                id += 1;
            }
        }
        assert_eq!(flows.len(), 16 * n);
        let groups = coalesce(&flows);
        assert_eq!(groups.len(), 2);
        let ba = groups.iter().find(|g| g.src == 1).unwrap();
        assert_eq!(ba.num_flows, 10 * n);
        assert!((ba.volume - 10.0 * n as f64 * GB).abs() < 1e-9);
    }

    #[test]
    fn coalesce_drops_intra_dc_and_empty() {
        let flows =
            vec![flow(0, 1, 1, 5.0), flow(1, 1, 2, 0.0), flow(2, 1, 2, 3.0), flow(3, 2, 1, 4.0)];
        let groups = coalesce(&flows);
        assert_eq!(groups.len(), 2);
        assert!(groups.iter().all(|g| g.volume > 0.0 && g.src != g.dst));
    }

    #[test]
    fn scale_down_matches_fig4() {
        let n = 10;
        let mut flows = Vec::new();
        for i in 0..16 * n {
            let src = if i < 10 * n { 1 } else { 2 };
            flows.push(flow(i as u64, src, 0, 1.0));
        }
        let c = Coflow::new(1, flows);
        assert!((c.scale_down() - 2.0 / (16.0 * n as f64)).abs() < 1e-12);
    }

    #[test]
    fn total_volume_sums() {
        let c = Coflow::new(1, vec![flow(0, 0, 1, 2.0), flow(1, 1, 0, 3.0)]);
        assert!((c.total_volume() - 5.0).abs() < 1e-12);
    }

    /// Regression: a non-positive or non-finite deadline used to be stored
    /// as-is and fed into Γ-ordering / admission arithmetic. It must now be
    /// treated as "no deadline".
    #[test]
    fn invalid_deadlines_are_rejected() {
        for bad in [0.0, -5.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let c = Coflow::new(1, vec![flow(0, 0, 1, 2.0)]).with_deadline(bad);
            assert_eq!(c.deadline, None, "deadline {bad} should have been rejected");
        }
        let c = Coflow::new(1, vec![flow(0, 0, 1, 2.0)]).with_deadline(3.5);
        assert_eq!(c.deadline, Some(3.5));
        // An invalid deadline must not clobber semantics either way: a
        // valid one followed by an invalid one ends at "no deadline".
        let c = c.with_deadline(f64::NAN);
        assert_eq!(c.deadline, None);
    }

    #[test]
    fn batch_is_the_structural_default() {
        assert_eq!(ServiceClass::default(), ServiceClass::Batch);
        assert_eq!(Coflow::new(1, Vec::new()).class, ServiceClass::Batch);
        assert_eq!(Coflow::default().class, ServiceClass::Batch);
        assert_eq!(ServiceClass::Batch.rate_floor(), None);
        assert_eq!(ServiceClass::Stream { rate_floor_gbps: 1.5 }.rate_floor(), Some(1.5));
        // A degenerate zero floor is no floor.
        assert_eq!(ServiceClass::Stream { rate_floor_gbps: 0.0 }.rate_floor(), None);
    }

    #[test]
    fn agg_tree_participants() {
        let t = AggTree { root: 2, edges: vec![(0, 2), (1, 2), (3, 1)] };
        assert_eq!(t.participants(), vec![0, 1, 2, 3]);
        assert_eq!(t.participants().len(), 4);
        assert_eq!(ServiceClass::MlSync { tree: t, iteration_gbit: 4.0 }.name(), "ml-sync");
    }
}
