//! Rapier (Zhao et al., INFOCOM'15) — baseline 5 (§6.1).
//!
//! The closest prior work: joint coflow scheduling **and** routing, but
//! designed for datacenters. Three differences from Terra that the paper
//! calls out (§7) and that this implementation reproduces:
//!
//! 1. **Flow granularity** — no FlowGroup coalescing: the optimization runs
//!    with one commodity per *flow*, which is what makes its scheduling
//!    rounds 26–29× slower (Fig 3 / Fig 11).
//! 2. **Single-path routing** — each flow is pinned to one path (the ILP's
//!    integral constraint); we solve the fractional relaxation and round to
//!    each flow's strongest path, the standard Rapier heuristic.
//! 3. **No work-conservation layering / α share** — it relies on δ-based
//!    time-division to avoid starvation; with δ = 20 (the best value found
//!    in §6.1) the schedule approximates SEBF priority with coarse rounds.

use crate::coflow::FlowGroup;
use crate::lp::{self, GroupDemand, McfInstance};
use crate::scheduler::*;
use std::time::Instant;

pub struct RapierPolicy {
    /// TDM quantum (δ): coflows scheduled strictly by remaining-size rank;
    /// within a quantum lower-priority coflows get leftovers only.
    pub delta: f64,
    stats: RoundStats,
}

impl Default for RapierPolicy {
    fn default() -> Self {
        RapierPolicy { delta: 20.0, stats: RoundStats::default() }
    }
}

impl RapierPolicy {
    /// Split each FlowGroup back into its constituent per-flow commodities
    /// (volume / num_flows each) — Rapier has no FlowGroup abstraction.
    fn per_flow_demands(
        cf: &CoflowState,
        caps: &[f64],
        net: &NetView,
        k: usize,
    ) -> (McfInstance, Vec<usize>) {
        let mut groups: Vec<GroupDemand> = Vec::new();
        let mut owner_group: Vec<usize> = Vec::new();
        for (gi, (g, &rem)) in cf.groups.iter().zip(&cf.remaining).enumerate() {
            if rem <= 1e-9 {
                continue;
            }
            let n = g.num_flows.max(1);
            let per = rem / n as f64;
            let paths: Vec<Vec<usize>> =
                net.paths.get(g.src, g.dst).iter().take(k).map(|p| p.edges.clone()).collect();
            for _ in 0..n {
                groups.push(GroupDemand { volume: per, paths: paths.clone() });
                owner_group.push(gi);
            }
        }
        (McfInstance { cap: caps.to_vec(), groups }, owner_group)
    }
}

impl Policy for RapierPolicy {
    fn name(&self) -> &'static str {
        "rapier"
    }

    fn allocate(
        &mut self,
        _now: f64,
        _trigger: RoundTrigger,
        coflows: &[CoflowState],
        net: &NetView,
    ) -> Allocation {
        let t0 = Instant::now();
        let caps = net.wan.capacities();
        let mut residual = caps.clone();
        let mut alloc = Allocation::default();

        // Priority: smallest remaining volume first (Rapier's OCCT-min
        // heuristic degenerates to this under uniform bandwidth).
        let mut order: Vec<usize> = (0..coflows.len()).collect();
        order.sort_by(|&a, &b| {
            coflows[a].total_remaining().total_cmp(&coflows[b].total_remaining())
        });

        for &ci in &order {
            let cf = &coflows[ci];
            if cf.done() {
                continue;
            }
            // Fractional relaxation at FLOW granularity (expensive — this is
            // the point of Fig 3/11).
            let (inst, owner_group) =
                Self::per_flow_demands(cf, &residual, net, DEFAULT_K);
            if inst.groups.is_empty() {
                continue;
            }
            let lp_t = Instant::now();
            let sol = lp::max_concurrent(&inst, lp::SolverKind::Gk);
            self.stats.lp_solves += 1;
            self.stats.lp_time_s += lp_t.elapsed().as_secs_f64();
            let Some(sol) = sol else { continue };

            // Integral rounding: pin each flow to its highest-rate path,
            // re-normalize so the single-path rates stay feasible.
            let mut pinned: Vec<(usize, usize, f64)> = Vec::new(); // (flow, path, want)
            for (fi, rates) in sol.rates.iter().enumerate() {
                let total: f64 = rates.iter().sum();
                if total <= 1e-12 {
                    continue;
                }
                let best = rates
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(p, _)| p)
                    .unwrap();
                pinned.push((fi, best, total));
            }
            // Feasibility after rounding: scale all of this coflow's flows
            // by the worst oversubscription.
            let mut usage = vec![0.0; residual.len()];
            for &(fi, p, want) in &pinned {
                for &e in &inst.groups[fi].paths[p] {
                    usage[e] += want;
                }
            }
            let mut scale: f64 = 1.0;
            for (u, r) in usage.iter().zip(&residual) {
                if *u > 1e-12 {
                    scale = scale.min(r / u);
                }
            }
            let scale = scale.clamp(0.0, 1.0);
            if scale <= 1e-12 {
                continue;
            }
            let entry =
                alloc.rates.entry(cf.id).or_insert_with(|| vec![Vec::new(); cf.groups.len()]);
            for &(fi, p, want) in &pinned {
                let gi = owner_group[fi];
                let paths_len = net.paths.get(cf.groups[gi].src, cf.groups[gi].dst).len();
                if entry[gi].len() < paths_len {
                    entry[gi].resize(paths_len, 0.0);
                }
                let r = want * scale;
                entry[gi][p] += r;
                for &e in &inst.groups[fi].paths[p] {
                    residual[e] = (residual[e] - r).max(0.0);
                }
            }
        }

        self.stats.round_time_s += t0.elapsed().as_secs_f64();
        alloc
    }

    fn take_stats(&mut self) -> RoundStats {
        std::mem::take(&mut self.stats)
    }
}

/// Expose per-flow instance construction for the overhead benches (Fig 11).
pub fn per_flow_instance_size(groups: &[FlowGroup]) -> usize {
    groups.iter().map(|g| g.num_flows.max(1)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Coflow, Flow, GB};
    use crate::net::paths::PathSet;
    use crate::net::topologies;
    use crate::sim::{Job, SimConfig, Simulation};

    fn mk_flow(id: u64, s: usize, d: usize, gb: f64) -> Flow {
        Flow { id, src_dc: s, dst_dc: d, volume: gb * GB }
    }

    #[test]
    fn allocates_single_path_per_flow() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 15);
        let net = NetView { wan: &wan, paths: &paths };
        // One flow: after rounding it must use exactly one path.
        let cf = CoflowState::from_coflow(&Coflow::new(1, vec![mk_flow(0, 0, 1, 5.0)]));
        let mut p = RapierPolicy::default();
        let alloc = p.allocate(0.0, RoundTrigger::Initial, &[cf], &net);
        let rates = &alloc.rates[&1][0];
        let used_paths = rates.iter().filter(|&&r| r > 1e-9).count();
        assert_eq!(used_paths, 1, "rates={rates:?}");
    }

    #[test]
    fn multiple_flows_can_spread_over_paths() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 15);
        let net = NetView { wan: &wan, paths: &paths };
        // 8 flows A->B: individual flows pin to different paths, so the
        // aggregate exceeds one link's capacity.
        let flows: Vec<Flow> = (0..8).map(|i| mk_flow(i, 0, 1, 2.0)).collect();
        let cf = CoflowState::from_coflow(&Coflow::new(1, flows));
        let mut p = RapierPolicy::default();
        let alloc = p.allocate(0.0, RoundTrigger::Initial, &[cf.clone()], &net);
        let total: f64 = alloc.rates[&1].iter().flatten().sum();
        assert!(total > 10.0 + 1e-6, "total={total} should exceed one link");
        let usage = alloc.edge_usage(&[cf], &net, wan.num_edges());
        for (u, c) in usage.iter().zip(wan.capacities()) {
            assert!(*u <= c + 1e-6);
        }
    }

    #[test]
    fn e2e_worse_than_terra_on_fig1() {
        let wan = topologies::fig1a();
        let jobs = || {
            vec![
                Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]),
                Job::map_reduce(
                    2,
                    0.0,
                    0.0,
                    vec![mk_flow(0, 0, 1, 5.0), mk_flow(1, 2, 1, 25.0)],
                ),
            ]
        };
        let mut rapier =
            Simulation::new(wan.clone(), Box::new(RapierPolicy::default()), SimConfig::default());
        let rrep = rapier.run_jobs(jobs());
        let mut terra = Simulation::new(
            wan,
            Box::new(crate::scheduler::terra::TerraPolicy::new(
                crate::scheduler::terra::TerraConfig { alpha: 0.0, ..Default::default() },
            )),
            SimConfig::default(),
        );
        let trep = terra.run_jobs(jobs());
        assert!(rrep.unfinished() == 0);
        assert!(
            trep.avg_cct() <= rrep.avg_cct() + 1e-6,
            "terra {} rapier {}",
            trep.avg_cct(),
            rrep.avg_cct()
        );
    }

    #[test]
    fn per_flow_size_counts_flows() {
        let cf = CoflowState::from_coflow(&Coflow::new(
            1,
            (0..10).map(|i| mk_flow(i, 0, 1, 1.0)).collect(),
        ));
        assert_eq!(per_flow_instance_size(&cf.groups), 10);
    }
}
