//! Varys (Chowdhury et al., SIGCOMM'14): SEBF + MADD coflow scheduling —
//! baseline 4 (§6.1, Fig 1e).
//!
//! Varys assumes a **non-blocking** fabric where contention exists only at
//! endpoint up/downlinks. On a WAN we map each datacenter's "uplink" to the
//! sum of its outgoing edge capacities (and "downlink" to incoming). SEBF
//! orders coflows by their non-blocking bottleneck completion time Γ_nb;
//! MADD gives each FlowGroup rate `volume/Γ_nb` so everything finishes
//! together. Because the WAN is *not* non-blocking and Varys is
//! single-path, the computed rates are clamped to actual shortest-path
//! residuals — exactly the mismatch the paper exploits (§2.4). Leftover
//! capacity is backfilled (Varys' work conservation).

use crate::lp::maxmin;
use crate::scheduler::*;
use std::time::Instant;

#[derive(Default)]
pub struct VarysPolicy {
    stats: RoundStats,
}

/// Non-blocking bottleneck CCT (MADD's Γ): max over endpoints of
/// volume / endpoint capacity.
pub fn gamma_nonblocking(cf: &CoflowState, net: &NetView) -> f64 {
    let n = net.wan.num_nodes();
    let mut out_vol = vec![0.0; n];
    let mut in_vol = vec![0.0; n];
    for (g, &rem) in cf.groups.iter().zip(&cf.remaining) {
        out_vol[g.src] += rem;
        in_vol[g.dst] += rem;
    }
    let mut gamma: f64 = 0.0;
    for u in 0..n {
        let egress: f64 = net.wan.out_edges(u).iter().map(|&e| net.wan.link(e).avail()).sum();
        let ingress: f64 = net.wan.in_edges(u).iter().map(|&e| net.wan.link(e).avail()).sum();
        if out_vol[u] > 0.0 {
            gamma = gamma.max(if egress > 0.0 { out_vol[u] / egress } else { f64::INFINITY });
        }
        if in_vol[u] > 0.0 {
            gamma = gamma.max(if ingress > 0.0 { in_vol[u] / ingress } else { f64::INFINITY });
        }
    }
    gamma
}

impl Policy for VarysPolicy {
    fn name(&self) -> &'static str {
        "varys"
    }

    /// Varys routes on the single shortest path.
    fn k_paths(&self) -> usize {
        1
    }

    fn allocate(
        &mut self,
        _now: f64,
        _trigger: RoundTrigger,
        coflows: &[CoflowState],
        net: &NetView,
    ) -> Allocation {
        let t0 = Instant::now();
        let caps = net.wan.capacities();
        let mut residual = caps.clone();
        let mut alloc = Allocation::default();

        // SEBF: smallest effective bottleneck (non-blocking Γ) first.
        let mut order: Vec<(usize, f64)> = coflows
            .iter()
            .enumerate()
            .map(|(i, cf)| (i, gamma_nonblocking(cf, net)))
            .collect();
        order.sort_by(|a, b| a.1.total_cmp(&b.1));

        for &(i, gamma) in &order {
            let cf = &coflows[i];
            if gamma <= 0.0 || !gamma.is_finite() {
                continue;
            }
            // MADD rates on shortest paths, scaled down together if the real
            // (blocking) WAN cannot carry them. Feasibility must be JOINT:
            // multiple groups of the coflow can share a WAN edge.
            let mut want: Vec<(usize, f64, &[usize])> = Vec::new(); // (group, rate, path)
            for (gi, (g, &rem)) in cf.groups.iter().zip(&cf.remaining).enumerate() {
                if rem <= 1e-9 {
                    continue;
                }
                let paths = net.paths.get(g.src, g.dst);
                let Some(p) = paths.first() else { continue };
                want.push((gi, rem / gamma, &p.edges));
            }
            if want.is_empty() {
                continue;
            }
            let mut usage = vec![0.0f64; residual.len()];
            for &(_, rate, path) in &want {
                for &e in path {
                    usage[e] += rate;
                }
            }
            let mut feas: f64 = 1.0;
            for (u, r) in usage.iter().zip(&residual) {
                if *u > 1e-12 {
                    feas = feas.min(r / u);
                }
            }
            let scale = feas.clamp(0.0, 1.0);
            if scale <= 1e-12 {
                continue;
            }
            let entry =
                alloc.rates.entry(cf.id).or_insert_with(|| vec![Vec::new(); cf.groups.len()]);
            for (gi, rate, path) in want {
                let r = rate * scale;
                entry[gi] = vec![r];
                for &e in path {
                    residual[e] = (residual[e] - r).max(0.0);
                }
            }
        }

        // Backfill (work conservation) with per-group max-min on leftovers.
        let mut demands = Vec::new();
        let mut owners = Vec::new();
        for (ci, cf) in coflows.iter().enumerate() {
            let (inst, index) = build_instance(&cf.groups, &cf.remaining, &residual, net, 1);
            for (ii, d) in inst.groups.into_iter().enumerate() {
                owners.push((ci, index[ii]));
                demands.push(d);
            }
        }
        if !demands.is_empty() {
            let weights: Vec<f64> = demands.iter().map(|d| d.volume).collect();
            let bonus = maxmin::max_min_rates(&residual, &demands, &weights);
            for (di, &(ci, gi)) in owners.iter().enumerate() {
                let cf = &coflows[ci];
                let entry =
                    alloc.rates.entry(cf.id).or_insert_with(|| vec![Vec::new(); cf.groups.len()]);
                if entry[gi].is_empty() {
                    entry[gi] = vec![0.0];
                }
                entry[gi][0] += bonus[di].first().copied().unwrap_or(0.0);
            }
        }

        self.stats.lp_solves += 1;
        self.stats.round_time_s += t0.elapsed().as_secs_f64();
        alloc
    }

    fn take_stats(&mut self) -> RoundStats {
        std::mem::take(&mut self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Coflow, Flow, GB};
    use crate::net::topologies;
    use crate::sim::{Job, SimConfig, Simulation};

    fn mk_flow(id: u64, s: usize, d: usize, gb: f64) -> Flow {
        Flow { id, src_dc: s, dst_dc: d, volume: gb * GB }
    }

    /// Paper Fig 1e: intra-datacenter coflow scheduling (Varys-like)
    /// averages 12 s — Coflow-1 preempts on A->B (4 s), Coflow-2 takes 20 s.
    #[test]
    fn fig1e_average() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, Box::new(VarysPolicy::default()), SimConfig::default());
        let j1 = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let j2 = Job::map_reduce(
            2,
            0.0,
            0.0,
            vec![mk_flow(0, 0, 1, 5.0), mk_flow(1, 2, 1, 25.0)],
        );
        let rep = sim.run_jobs(vec![j1, j2]);
        let avg = rep.avg_cct();
        // Single-path + SEBF: C1 ≈ 4 s, C2 = 20 s, average ≈ 12 s.
        assert!((avg - 12.0).abs() < 1.0, "avg={avg}");
    }

    #[test]
    fn gamma_nb_bottleneck() {
        let wan = topologies::fig1a();
        let paths = crate::net::paths::PathSet::compute(&wan, 1);
        let net = NetView { wan: &wan, paths: &paths };
        // 40 Gbit out of A; A's egress = 20 Gbps => Γ_nb = 2 s.
        let cf = CoflowState::from_coflow(&Coflow::new(1, vec![mk_flow(0, 0, 1, 5.0)]));
        let g = gamma_nonblocking(&cf, &net);
        assert!((g - 2.0).abs() < 1e-9, "g={g}");
    }

    #[test]
    fn respects_capacity() {
        let wan = topologies::fig1a();
        let paths = crate::net::paths::PathSet::compute(&wan, 1);
        let net = NetView { wan: &wan, paths: &paths };
        let cfs: Vec<CoflowState> = (0..4)
            .map(|i| {
                CoflowState::from_coflow(&Coflow::new(
                    i,
                    vec![mk_flow(0, 0, 1, 10.0), mk_flow(1, 2, 1, 5.0)],
                ))
            })
            .collect();
        let mut p = VarysPolicy::default();
        let alloc = p.allocate(0.0, RoundTrigger::Initial, &cfs, &net);
        let usage = alloc.edge_usage(&cfs, &net, wan.num_edges());
        for (u, c) in usage.iter().zip(wan.capacities()) {
            assert!(*u <= c + 1e-6, "usage {u} > {c}");
        }
    }
}
