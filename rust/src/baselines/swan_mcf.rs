//! SWAN-MCF (Hong et al., SIGCOMM'13) — baseline 3 (§6.1).
//!
//! SWAN is a WAN-side traffic engineer: it maximizes network throughput with
//! approximate max-min fairness across *demands* (datacenter-pair
//! aggregates), using multipath routing, but it is application-agnostic —
//! it has no notion of coflows, so it cannot prioritize a small coflow's
//! straggler FlowGroup over a big coflow's bulk (§2.4). We model it as
//! weighted max-min MCF over all active FlowGroups at every round.

use crate::lp::{maxmin, GroupDemand};
use crate::scheduler::*;
use std::time::Instant;

#[derive(Default)]
pub struct SwanMcfPolicy {
    stats: RoundStats,
}

impl Policy for SwanMcfPolicy {
    fn name(&self) -> &'static str {
        "swan-mcf"
    }

    fn allocate(
        &mut self,
        _now: f64,
        _trigger: RoundTrigger,
        coflows: &[CoflowState],
        net: &NetView,
    ) -> Allocation {
        let t0 = Instant::now();
        let caps = net.wan.capacities();
        let mut demands: Vec<GroupDemand> = Vec::new();
        let mut owners: Vec<(usize, usize)> = Vec::new();
        for (ci, cf) in coflows.iter().enumerate() {
            let (inst, index) = build_instance(&cf.groups, &cf.remaining, &caps, net, DEFAULT_K);
            for (ii, d) in inst.groups.into_iter().enumerate() {
                demands.push(d);
                owners.push((ci, index[ii]));
            }
        }
        let mut alloc = Allocation::default();
        if demands.is_empty() {
            return alloc;
        }
        // SWAN's fairness unit is the demand (FlowGroup aggregate), equal
        // weights — unaware of which application the bytes belong to.
        let weights = vec![1.0; demands.len()];
        let rates = maxmin::max_min_rates(&caps, &demands, &weights);
        for (di, &(ci, gi)) in owners.iter().enumerate() {
            let cf = &coflows[ci];
            let entry =
                alloc.rates.entry(cf.id).or_insert_with(|| vec![Vec::new(); cf.groups.len()]);
            entry[gi] = rates[di].clone();
        }
        self.stats.lp_solves += 1;
        self.stats.round_time_s += t0.elapsed().as_secs_f64();
        alloc
    }

    fn take_stats(&mut self) -> RoundStats {
        std::mem::take(&mut self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Flow, GB};
    use crate::net::topologies;
    use crate::sim::{Job, SimConfig, Simulation};

    fn mk_flow(id: u64, s: usize, d: usize, gb: f64) -> Flow {
        Flow { id, src_dc: s, dst_dc: d, volume: gb * GB }
    }

    #[test]
    fn beats_per_flow_via_multipath_but_not_terra() {
        let wan = topologies::fig1a();
        let jobs = |_: ()| {
            vec![
                Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]),
                Job::map_reduce(
                    2,
                    0.0,
                    0.0,
                    vec![mk_flow(0, 0, 1, 5.0), mk_flow(1, 2, 1, 25.0)],
                ),
            ]
        };
        let mut swan =
            Simulation::new(wan.clone(), Box::new(SwanMcfPolicy::default()), SimConfig::default());
        let swan_rep = swan.run_jobs(jobs(()));
        let mut terra = Simulation::new(
            wan,
            Box::new(crate::scheduler::terra::TerraPolicy::new(
                crate::scheduler::terra::TerraConfig { alpha: 0.0, ..Default::default() },
            )),
            SimConfig::default(),
        );
        let terra_rep = terra.run_jobs(jobs(()));
        assert!(
            terra_rep.avg_cct() <= swan_rep.avg_cct() + 1e-6,
            "terra {} vs swan {}",
            terra_rep.avg_cct(),
            swan_rep.avg_cct()
        );
        // SWAN still uses multiple paths, so it beats single-path fair 14 s.
        assert!(swan_rep.avg_cct() < 14.0, "swan avg {}", swan_rep.avg_cct());
    }
}
