//! Per-flow fair sharing (single-path "ideal TCP") and its multipath
//! extension ("ideal MPTCP") — baselines 1 and 2 (§6.1, Fig 1c/1d).
//!
//! Every *flow* gets a max-min fair share; a FlowGroup of `n` flows weighs
//! `n` shares (its constituent flows all follow the same route set, so their
//! aggregate equals a weight-n entity). Single-path mode pins each
//! FlowGroup to its shortest path; multipath mode spreads across all k.

use crate::lp::{maxmin, GroupDemand};
use crate::scheduler::*;
use std::time::Instant;

/// Application-agnostic fair-sharing policy.
pub struct FairPolicy {
    /// Use all k paths (true) or only the shortest (false).
    pub multipath: bool,
    stats: RoundStats,
}

impl FairPolicy {
    pub fn per_flow() -> FairPolicy {
        FairPolicy { multipath: false, stats: RoundStats::default() }
    }

    pub fn multipath() -> FairPolicy {
        FairPolicy { multipath: true, stats: RoundStats::default() }
    }
}

impl Policy for FairPolicy {
    fn name(&self) -> &'static str {
        if self.multipath {
            "multipath"
        } else {
            "per-flow"
        }
    }

    fn k_paths(&self) -> usize {
        if self.multipath {
            DEFAULT_K
        } else {
            1
        }
    }

    fn allocate(
        &mut self,
        _now: f64,
        _trigger: RoundTrigger,
        coflows: &[CoflowState],
        net: &NetView,
    ) -> Allocation {
        let t0 = Instant::now();
        let caps = net.wan.capacities();
        let k = self.k_paths();
        let mut demands: Vec<GroupDemand> = Vec::new();
        let mut weights: Vec<f64> = Vec::new();
        let mut owners: Vec<(usize, usize)> = Vec::new();
        for (ci, cf) in coflows.iter().enumerate() {
            let (inst, index) = build_instance(&cf.groups, &cf.remaining, &caps, net, k);
            for (ii, d) in inst.groups.into_iter().enumerate() {
                let gi = index[ii];
                weights.push(cf.groups[gi].num_flows.max(1) as f64);
                demands.push(d);
                owners.push((ci, gi));
            }
        }
        let mut alloc = Allocation::default();
        if demands.is_empty() {
            return alloc;
        }
        let rates = maxmin::max_min_rates(&caps, &demands, &weights);
        for (di, &(ci, gi)) in owners.iter().enumerate() {
            let cf = &coflows[ci];
            let entry =
                alloc.rates.entry(cf.id).or_insert_with(|| vec![Vec::new(); cf.groups.len()]);
            entry[gi] = rates[di].clone();
        }
        self.stats.lp_solves += 1;
        self.stats.round_time_s += t0.elapsed().as_secs_f64();
        alloc
    }

    fn take_stats(&mut self) -> RoundStats {
        std::mem::take(&mut self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Flow, GB};
    use crate::net::topologies;
    use crate::sim::{Job, SimConfig, Simulation};

    fn mk_flow(id: u64, s: usize, d: usize, gb: f64) -> Flow {
        Flow { id, src_dc: s, dst_dc: d, volume: gb * GB }
    }

    /// Paper Fig 1c: per-flow fair sharing averages 14 s on the motivating
    /// example (f11 & f21 split A->B evenly -> both 8 s; f22 20 s alone).
    #[test]
    fn fig1c_per_flow_fair() {
        let wan = topologies::fig1a();
        let mut sim =
            Simulation::new(wan, Box::new(FairPolicy::per_flow()), SimConfig::default());
        let j1 = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let j2 = Job::map_reduce(
            2,
            0.0,
            0.0,
            vec![mk_flow(0, 0, 1, 5.0), mk_flow(1, 2, 1, 25.0)],
        );
        let rep = sim.run_jobs(vec![j1, j2]);
        let ccts: Vec<f64> = rep.coflows.iter().filter_map(|c| c.cct()).collect();
        let avg = rep.avg_cct();
        // Coflow-1: A->B shared until C1 finishes at 8 s; Coflow-2: 20 s.
        assert!((avg - 14.0).abs() < 0.8, "avg={avg} ccts={ccts:?}");
    }

    /// Paper Fig 1d: multipath fair sharing averages 10.6 s.
    #[test]
    fn fig1d_multipath_fair() {
        let wan = topologies::fig1a();
        let mut sim =
            Simulation::new(wan, Box::new(FairPolicy::multipath()), SimConfig::default());
        let j1 = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let j2 = Job::map_reduce(
            2,
            0.0,
            0.0,
            vec![mk_flow(0, 0, 1, 5.0), mk_flow(1, 2, 1, 25.0)],
        );
        let rep = sim.run_jobs(vec![j1, j2]);
        let avg = rep.avg_cct();
        // Ideal multipath fair sharing lands near the paper's 10.6 s
        // (exact value depends on the fairness refinement; max-min gives
        // a slightly better 9-11 s band).
        assert!(avg < 12.0 && avg > 8.0, "avg={avg}");
    }

    #[test]
    fn weights_favor_many_flow_groups() {
        // Group with 9 flows vs group with 1 flow on the same link: the
        // 9-flow group should take ~9x the bandwidth.
        let wan = topologies::fig1a();
        let paths = crate::net::paths::PathSet::compute(&wan, 1);
        let net = NetView { wan: &wan, paths: &paths };
        let mut many = Vec::new();
        for i in 0..9 {
            many.push(mk_flow(i, 0, 1, 1.0));
        }
        let c1 = CoflowState::from_coflow(&crate::coflow::Coflow::new(1, many));
        let c2 = CoflowState::from_coflow(&crate::coflow::Coflow::new(
            2,
            vec![mk_flow(0, 0, 1, 1.0)],
        ));
        let mut p = FairPolicy::per_flow();
        let alloc = p.allocate(0.0, RoundTrigger::Initial, &[c1, c2], &net);
        let r1: f64 = alloc.rates[&1].iter().flatten().sum();
        let r2: f64 = alloc.rates[&2].iter().flatten().sum();
        assert!(r1 > 6.0 * r2, "r1={r1} r2={r2}");
    }
}
