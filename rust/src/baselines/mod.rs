//! The five baselines Terra is evaluated against (§6.1):
//!
//! 1. [`per_flow::FairPolicy`] (`FairPolicy::per_flow()`) — ideal
//!    single-path per-flow fair sharing (TCP stand-in),
//! 2. `FairPolicy::multipath()` — its ideal multipath extension (MPTCP
//!    stand-in),
//! 3. [`swan_mcf::SwanMcfPolicy`] — SWAN's application-agnostic max-min MCF
//!    WAN optimizer,
//! 4. [`varys::VarysPolicy`] — SEBF + MADD coflow scheduling assuming a
//!    non-blocking core (contention only at datacenter up/downlinks),
//! 5. [`rapier::RapierPolicy`] — joint scheduling + *single-path* routing at
//!    *flow* granularity (no FlowGroups).
//!
//! All run behind the same [`crate::scheduler::Policy`] interface as Terra,
//! in the same simulator and over the same PathSets.

pub mod per_flow;
pub mod rapier;
pub mod swan_mcf;
pub mod varys;

pub use per_flow::FairPolicy;
pub use rapier::RapierPolicy;
pub use swan_mcf::SwanMcfPolicy;
pub use varys::VarysPolicy;

use crate::scheduler::Policy;

/// Instantiate a policy by CLI name. `terra` gets paper defaults.
pub fn by_name(name: &str) -> Option<Box<dyn Policy>> {
    match name.to_ascii_lowercase().as_str() {
        "terra" => Some(Box::new(crate::scheduler::TerraPolicy::default())),
        "per-flow" | "perflow" | "tcp" => Some(Box::new(FairPolicy::per_flow())),
        "multipath" | "mptcp" => Some(Box::new(FairPolicy::multipath())),
        "swan-mcf" | "swan" => Some(Box::new(SwanMcfPolicy::default())),
        "varys" => Some(Box::new(VarysPolicy::default())),
        "rapier" => Some(Box::new(RapierPolicy::default())),
        _ => None,
    }
}

/// All evaluation policies in the paper's table order (Terra last).
pub fn all_policy_names() -> &'static [&'static str] {
    &["per-flow", "varys", "swan-mcf", "multipath", "rapier", "terra"]
}

#[cfg(test)]
mod tests {
    #[test]
    fn by_name_covers_all() {
        for n in super::all_policy_names() {
            assert!(super::by_name(n).is_some(), "{n}");
        }
        assert!(super::by_name("bogus").is_none());
    }
}
