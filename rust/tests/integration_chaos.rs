//! Chaos tests on the real testbed: kill the controller mid-transfer and
//! prove the fault-tolerance story end to end — agents fall back to
//! degraded fair-share draining (within the last-known allocation
//! envelope), a restarted controller rebuilds its world from `resync_state`
//! reports with achieved bytes intact (nothing restarts from zero), and
//! completions observed during the outage still reach the new controller.
//! The mirror-image drill kills an *agent* instead: the controller's
//! liveness deadline detects the silence, parks the victim's coflows with
//! progress preserved, keeps scheduling the survivors around the hole, and
//! re-arms a replacement agent from the preserved remaining.

use std::time::{Duration, Instant};
use terra::api::TerraClient;
use terra::net::topologies;
use terra::overlay::protocol::FlowSpec;
use terra::overlay::{Agent, Controller, ControllerHandle, TestbedConfig, BYTES_PER_GBPS};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};

const K: usize = 3;

/// Spawn a controller for fig1a — callable twice, because that is the
/// point: the second spawn is the "restarted" controller on a fresh
/// address (the std listener cannot rebind the old ephemeral port, which
/// conveniently models a failover to a different replica behind a VIP).
fn spawn_controller() -> ControllerHandle {
    let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, k: K, ..Default::default() });
    Controller::spawn(TestbedConfig::new(topologies::fig1a(), K), Box::new(policy)).unwrap()
}

fn spawn_agents(handle: &ControllerHandle) -> Vec<Agent> {
    let agents: Vec<Agent> = (0..3).map(|dc| Agent::spawn(dc, handle.addr).unwrap()).collect();
    assert!(handle.wait_ready(3, Duration::from_secs(10)), "agents failed to register");
    agents
}

/// 1 emulated Gbit as testbed bytes.
fn gbit(x: f64) -> u64 {
    (x * BYTES_PER_GBPS) as u64
}

fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    false
}

/// The tentpole drill: kill the controller under a long transfer, watch the
/// sending agent degrade gracefully, restart the controller on a new
/// address, and verify crash reconstruction (progress preserved, degraded
/// mode exits, allocations reconcile back to the pre-crash scale, transfer
/// completes and its completion lands in the *new* controller).
#[test]
fn controller_crash_restart_preserves_transfer_progress() {
    const VOLUME: f64 = 100.0; // ~5 s at fig1a's 20 Gbps aggregate
    let handle = spawn_controller();
    let agents = spawn_agents(&handle);

    let mut client = TerraClient::connect(handle.addr).unwrap();
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(VOLUME) }];
    let cid = client.submit_coflow(&flows, None).unwrap() as u64;
    // Let it make real progress, and remember the controller's envelope.
    assert!(
        wait_until(Duration::from_secs(5), || agents[1].received_bytes(cid, 0) > gbit(2.0)),
        "transfer never got going"
    );
    let (pre_alloc, _) = agents[0].outgoing_rates(cid, 1).expect("no outgoing transfer state");
    let pre_total: f64 = pre_alloc.iter().sum();
    assert!(pre_total > 0.0, "controller never rated the transfer");

    // Crash the controller mid-transfer.
    handle.shutdown();

    // The sender must notice the silence (heartbeat deadline) and engage
    // degraded mode...
    assert!(
        wait_until(Duration::from_secs(6), || agents[0].is_degraded()),
        "degraded mode never engaged after controller death"
    );
    // ...enforcing rates strictly within the last-known envelope...
    let (alloc, rate) = agents[0].outgoing_rates(cid, 1).unwrap();
    let (alloc_sum, rate_sum) = (alloc.iter().sum::<f64>(), rate.iter().sum::<f64>());
    assert!(rate_sum > 0.0, "degraded mode must keep draining, not park the transfer");
    assert!(
        rate_sum <= alloc_sum * 0.5 + 1e-9,
        "degraded rate {rate_sum} exceeds half the envelope {alloc_sum}"
    );
    // ...and bytes must keep flowing with no controller anywhere.
    let rx0 = agents[1].received_bytes(cid, 0);
    std::thread::sleep(Duration::from_millis(400));
    let rx1 = agents[1].received_bytes(cid, 0);
    assert!(rx1 > rx0, "degraded drain stalled: {rx0} -> {rx1}");

    // Restart: new controller, new address; agents re-resolve and resync.
    let rx_pre = agents[1].received_bytes(cid, 0);
    let handle2 = spawn_controller();
    for a in &agents {
        a.redirect_controller(handle2.addr);
    }
    assert!(handle2.wait_ready(3, Duration::from_secs(10)), "agents failed to reconnect");

    // Reconstruction: the coflow reappears in the new controller's engine
    // with the agents' achieved bytes credited — never from zero.
    assert!(
        wait_until(Duration::from_secs(5), || handle2.coflow_remaining_gbit(cid).is_some()),
        "resync_state never rebuilt the coflow"
    );
    let rem = handle2.coflow_remaining_gbit(cid).unwrap();
    let rx_pre_gbit = rx_pre as f64 / BYTES_PER_GBPS;
    assert!(
        rem <= VOLUME - rx_pre_gbit + 1.0,
        "progress lost in reconstruction: remaining {rem} of {VOLUME}, \
         receiver already had {rx_pre_gbit}"
    );

    // The new session's rates_full baseline ends degraded mode, and the
    // re-derived allocation converges back to the pre-crash scale (same
    // WAN, same lone coflow => same bottleneck, within the ρ gate).
    assert!(
        wait_until(Duration::from_secs(5), || !agents[0].is_degraded()),
        "degraded mode never exited after reconnect"
    );
    assert!(
        wait_until(Duration::from_secs(5), || {
            agents[0]
                .outgoing_rates(cid, 1)
                .map(|(_, r)| r.iter().sum::<f64>() >= 0.6 * pre_total)
                .unwrap_or(false)
        }),
        "post-reconcile allocation never returned to the pre-crash scale"
    );

    // And the transfer completes end to end, with the completion reaching
    // the restarted controller (remaining drops to None when it finishes).
    assert!(
        wait_until(Duration::from_secs(30), || agents[1].received_bytes(cid, 0) >= gbit(VOLUME)),
        "transfer never completed after recovery"
    );
    assert!(
        wait_until(Duration::from_secs(5), || handle2.coflow_remaining_gbit(cid).is_none()),
        "completion never reached the restarted controller"
    );
    // The whole drill must not have cost a single poisoned lock.
    assert_eq!(
        terra::overlay::agent::lock_poison_recoveries(),
        0,
        "a lock was poisoned during the crash drill"
    );
    for a in agents {
        a.shutdown();
    }
    handle2.shutdown();
}

/// A FlowGroup that finishes while no controller exists: the receiver
/// buffers the undeliverable `group_done` and replays it after resync. The
/// restarted controller never learned the coflow (the sender's transfer
/// state was already gone before resync), so the replay references an
/// unknown id — it must be absorbed, and the controller must stay fully
/// serviceable afterwards.
#[test]
fn completion_during_outage_reaches_restarted_controller() {
    let handle = spawn_controller();
    let agents = spawn_agents(&handle);

    let mut client = TerraClient::connect(handle.addr).unwrap();
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(10.0) }];
    let cid = client.submit_coflow(&flows, None).unwrap() as u64;
    assert!(
        wait_until(Duration::from_secs(5), || agents[1].received_bytes(cid, 0) > 0),
        "transfer never started"
    );
    handle.shutdown();

    // With the controller gone the agent keeps draining on its last-known
    // rates; the transfer *finishes* during the outage.
    assert!(
        wait_until(Duration::from_secs(10), || agents[1].received_bytes(cid, 0) >= gbit(10.0)),
        "drain stalled during the outage"
    );

    let handle2 = spawn_controller();
    for a in &agents {
        a.redirect_controller(handle2.addr);
    }
    assert!(handle2.wait_ready(3, Duration::from_secs(10)), "agents failed to reconnect");
    // Give the replayed group_done time to be absorbed before reusing ids.
    std::thread::sleep(Duration::from_millis(300));

    // Serviceability probe on a different source dc, so a reused coflow id
    // cannot alias the replayed (src, dst) completion.
    let mut client2 = TerraClient::connect(handle2.addr).unwrap();
    let flows = [FlowSpec { id: 0, src_dc: 2, dst_dc: 1, bytes: gbit(2.0) }];
    let cid2 = client2.submit_coflow(&flows, None).unwrap();
    assert!(cid2 > 0, "restarted controller rejected a fresh coflow");
    let cct = client2.wait_done(cid2 as u64, 15.0).unwrap();
    assert!(cct > 0.0);
    assert!(agents[1].received_bytes(cid2 as u64, 2) >= gbit(2.0));
    for a in agents {
        a.shutdown();
    }
    handle2.shutdown();
}

/// The data-plane mirror of the controller-crash drill: kill an *agent*
/// mid-transfer. The controller must notice the silence within the
/// liveness deadline (the agents' 250 ms telemetry stream is the
/// heartbeat), park the victim's coflow with achieved progress preserved,
/// keep the survivors' traffic flowing throughout the outage, and — when a
/// replacement agent registers for the dead site — re-arm the transfer
/// from the preserved remaining (never from zero) and drive it to
/// completion.
#[test]
fn agent_kill_is_detected_parked_and_resumed_from_achieved_bytes() {
    const VOLUME: f64 = 120.0; // victim: ~6 s at fig1a's 20 Gbps aggregate
    const SURVIVOR: f64 = 80.0;
    let deadline = Duration::from_secs(2);
    let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, k: K, ..Default::default() });
    let cfg = TestbedConfig::new(topologies::fig1a(), K).with_liveness_deadline(deadline);
    let handle = Controller::spawn(cfg, Box::new(policy)).unwrap();
    let mut agents = spawn_agents(&handle);
    // After this remove, agents[0] is dc 1 (the receiver) and agents[1] is
    // dc 2 (the survivor's sender).
    let victim_sender = agents.remove(0);

    // Victim coflow 0→1 plus a survivor 2→1 that spans the whole outage.
    let mut client = TerraClient::connect(handle.addr).unwrap();
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(VOLUME) }];
    let cid = client.submit_coflow(&flows, None).unwrap() as u64;
    let flows = [FlowSpec { id: 0, src_dc: 2, dst_dc: 1, bytes: gbit(SURVIVOR) }];
    let cid_s = client.submit_coflow(&flows, None).unwrap() as u64;
    assert!(
        wait_until(Duration::from_secs(5), || agents[0].received_bytes(cid, 0) > gbit(8.0)
            && agents[0].received_bytes(cid_s, 2) > 0),
        "transfers never got going"
    );

    // Kill the victim's sending agent: threads die, sockets close, and —
    // crucially — nothing polite is said to the controller.
    let killed = agents[0].received_bytes(cid, 0);
    let t_kill = Instant::now();
    victim_sender.shutdown();

    // Detection is deadline-driven: the controller must declare the site
    // down once the agent's channel is silent past the liveness deadline —
    // neither instantly (EOF alone is not death) nor late.
    assert!(
        wait_until(Duration::from_secs(8), || handle.agent_down(0)),
        "dead agent never declared down"
    );
    let elapsed = t_kill.elapsed();
    assert!(
        elapsed >= Duration::from_secs(1) && elapsed <= deadline + Duration::from_secs(3),
        "detection latency {elapsed:?} not anchored to the {deadline:?} deadline"
    );
    assert_eq!(handle.liveness_stats().down_events, 1);

    // The victim is parked — progress preserved, not finished, not dropped
    // — while the survivor (no endpoint at the dead site) is not.
    assert_eq!(handle.parked_coflows(), 1, "exactly the victim must be parked");
    let killed_gbit = killed as f64 / BYTES_PER_GBPS;
    let rem = handle.coflow_remaining_gbit(cid).expect("victim dropped from the engine");
    assert!(
        rem <= VOLUME - killed_gbit + 1.0,
        "parked remaining {rem} of {VOLUME} ignores the {killed_gbit} Gbit already achieved"
    );
    assert!(rem > 5.0, "victim must not be spuriously completed by the kill");

    // Survivor traffic keeps flowing with a site dark: the controller
    // reschedules around the hole (the relay path through site 0 is gone;
    // the direct edge is not), and bytes keep arriving.
    let rx0 = agents[0].received_bytes(cid_s, 2);
    std::thread::sleep(Duration::from_millis(400));
    let rx1 = agents[0].received_bytes(cid_s, 2);
    assert!(rx1 > rx0, "survivor stalled during the outage: {rx0} -> {rx1}");

    // A replacement agent registers for the dead site: un-park, re-arm
    // (reset transfer sized from the preserved remaining), resume.
    let replacement = Agent::spawn(0, handle.addr).unwrap();
    assert!(
        wait_until(Duration::from_secs(8), || !handle.agent_down(0)
            && handle.parked_coflows() == 0),
        "replacement never un-parked the victim"
    );
    assert_eq!(handle.liveness_stats().up_events, 1);

    // Both transfers complete end to end, and the completions reach the
    // controller (remaining drops to None). The victim's budget came from
    // the preserved remaining, so this finishes in seconds — a from-zero
    // restart of 120 Gbit would blow well past the victim wait below.
    let cct_s = client.wait_done(cid_s, 30.0).unwrap();
    assert!(cct_s > 0.0);
    assert!(
        wait_until(Duration::from_secs(30), || handle.coflow_remaining_gbit(cid).is_none()),
        "re-armed victim transfer never completed"
    );
    assert_eq!(
        terra::overlay::agent::lock_poison_recoveries(),
        0,
        "a lock was poisoned during the agent-kill drill"
    );
    replacement.shutdown();
    for a in agents {
        a.shutdown();
    }
    handle.shutdown();
}
