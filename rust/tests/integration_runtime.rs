//! Runtime integration: the whole stack with the AOT JAX/PDHG solver on the
//! scheduling hot path (rust -> PJRT -> Pallas-lowered HLO), validated
//! against the native-solver run.

use std::sync::Arc;
use terra::net::topologies;
use terra::runtime::JaxSolver;
use terra::scheduler::terra::TerraPolicy;
use terra::sim::{SimConfig, Simulation};
use terra::workloads::{WorkloadConfig, WorkloadGen, WorkloadKind};

fn artifacts() -> Option<Arc<JaxSolver>> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(JaxSolver::load("artifacts").expect("load artifacts")))
}

#[test]
fn jax_solver_end_to_end_sim() {
    let Some(solver) = artifacts() else { return };
    let wan = topologies::swan();
    let mk_jobs = || {
        let cfg = WorkloadConfig::new(WorkloadKind::TpcH, 21);
        WorkloadGen::with_config(cfg).jobs(&wan, 6)
    };
    let mut native = Simulation::new(
        wan.clone(),
        Box::new(TerraPolicy::default()),
        SimConfig::default(),
    );
    let native_rep = native.run_jobs(mk_jobs());

    let mut jax = Simulation::new(
        wan.clone(),
        Box::new(TerraPolicy::default().with_jax(solver)),
        SimConfig::default(),
    );
    let jax_rep = jax.run_jobs(mk_jobs());

    assert_eq!(jax_rep.unfinished(), 0);
    // Same workload, interchangeable solvers: JCTs agree within the PDHG
    // approximation band.
    let ratio = jax_rep.avg_jct() / native_rep.avg_jct();
    assert!(
        (0.8..1.25).contains(&ratio),
        "jax avg JCT {} vs native {} (ratio {ratio})",
        jax_rep.avg_jct(),
        native_rep.avg_jct()
    );
}

#[test]
fn jax_solver_handles_all_swan_pairs() {
    let Some(solver) = artifacts() else { return };
    let wan = topologies::swan();
    let paths = terra::net::paths::PathSet::compute(&wan, 15);
    for s in 0..wan.num_nodes() {
        for d in 0..wan.num_nodes() {
            if s == d {
                continue;
            }
            let inst = terra::lp::McfInstance {
                cap: wan.capacities(),
                groups: vec![terra::lp::GroupDemand {
                    volume: 80.0,
                    paths: paths.get(s, d).iter().map(|p| p.edges.clone()).collect(),
                }],
            };
            let sol = solver.solve(&wan, &inst).expect("solve");
            inst.check(&sol, 1e-3).unwrap();
            assert!(sol.lambda > 0.0);
        }
    }
}
