//! Golden-trace regression tests: a fixed-seed WAN dynamics scenario on
//! each evaluation topology is replayed through the simulator and through a
//! spawned TCP controller, and both planes' round/event logs must be
//! **byte-identical** (they drive the same `engine::RoundEngine`). The
//! simulator's log — including the final rate allocation — is additionally
//! pinned against golden JSON under `tests/golden/`; regenerate with
//! `TERRA_BLESS=1 cargo test --test golden_scenarios` (missing files are
//! blessed automatically on first run).

use terra::api::TerraClient;
use terra::coflow::Flow;
use terra::net::dynamics::{self, DynamicsModel, DynamicsProfile, TimedLinkEvent};
use terra::net::{topologies, LinkEvent, Wan};
use terra::overlay::protocol::FlowSpec;
use terra::overlay::{Controller, TestbedConfig, BYTES_PER_GBPS};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::{CoflowRates, Policy};
use terra::sim::{Job, SimConfig, Simulation};
use terra::util::json::Json;

const K: usize = 3;
const SEED: u64 = 7;
const HORIZON_S: f64 = 30.0;

/// (src, dst, Gbit) of the scenario coflows. Volumes are enormous and
/// well-separated so (a) nothing completes inside the horizon — keeping the
/// per-event round deltas identical between virtual-time and wall-clock
/// replays — and (b) the SRTF Γ-ordering has no near-ties that the
/// controller's wall-clock drain could flip.
const COFLOWS: [(usize, usize, f64); 3] =
    [(0, 1, 500_000.0), (1, 2, 300_000.0), (2, 0, 150_000.0)];

fn policy() -> Box<dyn Policy> {
    Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, k: K, ..Default::default() }))
}

/// The scenario's dynamics: gentle diurnal fluctuation with rare random
/// failures, plus one deterministic fail/recover of the topology's first
/// link so every topology exercises a structural reaction.
fn scenario_events(wan: &Wan) -> Vec<TimedLinkEvent> {
    let profile = DynamicsProfile {
        name: "golden".into(),
        models: vec![
            DynamicsModel::Diurnal {
                period_s: 120.0,
                amplitude: 0.3,
                jitter: 0.02,
                interval_s: 10.0,
            },
            DynamicsModel::MarkovFailure { mtbf_s: 1500.0, mttr_s: 6.0 },
        ],
    };
    let mut events = dynamics::generate(wan, &profile, HORIZON_S, SEED);
    let l0 = &wan.links()[0];
    events.push(TimedLinkEvent { t: 13.25, ev: LinkEvent::Fail(l0.src, l0.dst) });
    events.push(TimedLinkEvent { t: 22.75, ev: LinkEvent::Recover(l0.src, l0.dst) });
    finalize_events(events)
}

/// The gray-failure scenario: links stay "up" but churn violently around a
/// low mean — the ρ-dampening / drift-promotion stress test (and, on the
/// estimation axis, the capacity estimator's). Dense parameters so the
/// 30 s horizon reliably produces episodes on every topology.
fn gray_events(wan: &Wan) -> Vec<TimedLinkEvent> {
    let profile = DynamicsProfile {
        name: "golden-gray".into(),
        models: vec![DynamicsModel::GrayFailure {
            mtbg_s: 40.0,
            episode_s: 12.0,
            low_frac: 0.15,
            churn_interval_s: 2.5,
            churn_amp: 0.5,
        }],
    };
    finalize_events(dynamics::generate(wan, &profile, HORIZON_S, SEED))
}

fn finalize_events(mut events: Vec<TimedLinkEvent>) -> Vec<TimedLinkEvent> {
    events.sort_by(|a, b| a.t.total_cmp(&b.t));
    // The per-event replay attributes rounds to one event per timestamp;
    // drop (measure-zero) timestamp collisions so the attribution is exact.
    events.dedup_by(|b, a| (b.t - a.t).abs() < 1e-9);
    events
}

/// One per-event log entry: everything both planes can observe about the
/// engine's reaction, and nothing wall-clock-dependent.
struct EventRecord {
    t: f64,
    ev: LinkEvent,
    /// Capacity epoch after the event.
    epoch: u64,
    /// Engine rounds this event triggered (1 for structural/≥ρ/drift, 0
    /// for a sub-ρ clamp).
    rounds_delta: usize,
}

/// Quantize for stable JSON (also caps golden-file churn from last-ulp
/// platform differences).
fn q6(x: f64) -> f64 {
    (x * 1e6).round() / 1e6
}

fn records_json(recs: &[EventRecord]) -> Json {
    Json::Arr(
        recs.iter()
            .map(|r| {
                let (kind, u, v, gbps) = match r.ev {
                    LinkEvent::Fail(u, v) => ("fail", u, v, None),
                    LinkEvent::Recover(u, v) => ("recover", u, v, None),
                    LinkEvent::SetBandwidth(u, v, g) => ("bw", u, v, Some(g)),
                };
                let mut o = Json::from_pairs([
                    ("t", Json::from(q6(r.t))),
                    ("kind", kind.into()),
                    ("u", u.into()),
                    ("v", v.into()),
                    ("epoch", r.epoch.into()),
                    ("rounds", r.rounds_delta.into()),
                ]);
                if let Some(g) = gbps {
                    o.set("gbps", q6(g).into());
                }
                o
            })
            .collect(),
    )
}

fn rates_json(rates: &[Option<CoflowRates>]) -> Json {
    Json::Arr(
        rates
            .iter()
            .map(|r| match r {
                None => Json::Null,
                Some(groups) => Json::Arr(
                    groups
                        .iter()
                        .map(|g| Json::Arr(g.iter().map(|&x| Json::Num(q6(x))).collect()))
                        .collect(),
                ),
            })
            .collect(),
    )
}

/// Simulator replay: inject the whole stream up front, then step the
/// virtual clock just past each event to read the engine's reaction.
fn sim_replay(wan: Wan, events: &[TimedLinkEvent]) -> (Vec<EventRecord>, Vec<Option<CoflowRates>>) {
    let mut sim = Simulation::new(wan, policy(), SimConfig::default());
    for (i, (s, d, gbit)) in COFLOWS.iter().enumerate() {
        sim.add_job(Job::map_reduce(
            i as u64 + 1,
            0.0,
            0.0,
            vec![Flow { id: 0, src_dc: *s, dst_dc: *d, volume: *gbit }],
        ));
    }
    for e in events {
        sim.add_wan_event(e.t, e.ev.clone());
    }
    sim.run_until(0.0); // arrivals + initial round
    let mut recs = Vec::new();
    let mut last_rounds = sim.engine().rounds();
    for (i, e) in events.iter().enumerate() {
        // Stop strictly between this event and the next so exactly one
        // event (and its round, if any) lands in the window.
        let stop = match events.get(i + 1) {
            Some(n) => e.t + (n.t - e.t).min(2e-4) / 2.0,
            None => e.t + 1e-4,
        };
        sim.run_until(stop);
        let rounds = sim.engine().rounds();
        recs.push(EventRecord {
            t: e.t,
            ev: e.ev.clone(),
            epoch: sim.engine().epoch(),
            rounds_delta: rounds - last_rounds,
        });
        last_rounds = rounds;
    }
    let rates = (1..=COFLOWS.len() as u64).map(|id| sim.allocation(id)).collect();
    (recs, rates)
}

/// Controller replay: submit the same coflows over TCP, inject the same
/// stream event by event, and read the same engine observables.
fn controller_replay(
    wan: Wan,
    events: &[TimedLinkEvent],
) -> (Vec<EventRecord>, Vec<Option<CoflowRates>>) {
    let handle = Controller::spawn(TestbedConfig::new(wan, K), policy()).expect("spawn");
    let mut client = TerraClient::connect(handle.addr).expect("connect");
    let mut ids = Vec::new();
    for (i, (s, d, gbit)) in COFLOWS.iter().enumerate() {
        let spec = FlowSpec {
            id: i as u64,
            src_dc: *s,
            dst_dc: *d,
            bytes: (gbit * BYTES_PER_GBPS) as u64,
        };
        let cid = client.submit_coflow(&[spec], None).expect("submit");
        assert!(cid > 0);
        ids.push(cid as u64);
    }
    let mut recs = Vec::new();
    let mut last_rounds = handle.rounds();
    for e in events {
        handle.inject_wan_event(e.ev.clone());
        let rounds = handle.rounds();
        recs.push(EventRecord {
            t: e.t,
            ev: e.ev.clone(),
            epoch: handle.epoch(),
            rounds_delta: rounds - last_rounds,
        });
        last_rounds = rounds;
    }
    let rates = ids.iter().map(|&id| handle.allocation(id)).collect();
    handle.shutdown();
    (recs, rates)
}

fn assert_rates_close(topo: &str, sim: &[Option<CoflowRates>], ctl: &[Option<CoflowRates>]) {
    assert_eq!(sim.len(), ctl.len());
    for (ci, (s, c)) in sim.iter().zip(ctl).enumerate() {
        let (Some(s), Some(c)) = (s, c) else {
            assert_eq!(s.is_some(), c.is_some(), "{topo}: coflow {ci} allocation presence");
            continue;
        };
        assert_eq!(s.len(), c.len(), "{topo}: coflow {ci} group count");
        for (gi, (gs, gc)) in s.iter().zip(c).enumerate() {
            assert_eq!(gs.len(), gc.len(), "{topo}: coflow {ci} group {gi} path count");
            for (pi, (rs, rc)) in gs.iter().zip(gc).enumerate() {
                assert!(
                    (rs - rc).abs() <= 1e-2 * (1.0 + rs.abs()),
                    "{topo}: coflow {ci} group {gi} path {pi}: sim {rs} vs controller {rc}"
                );
            }
        }
    }
}

fn golden_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(format!("{name}.json"))
}

fn run_scenario(name: &str, wan: Wan) {
    let events = scenario_events(&wan);
    assert!(
        events.iter().any(|e| matches!(e.ev, LinkEvent::Fail(..))),
        "{name}: scenario must include a structural event"
    );
    run_scenario_events(name, wan, events);
}

fn run_scenario_events(name: &str, wan: Wan, events: Vec<TimedLinkEvent>) {
    assert!(!events.is_empty(), "{name}: scenario generated no events");

    let (sim_recs, sim_rates) = sim_replay(wan.clone(), &events);
    let (ctl_recs, ctl_rates) = controller_replay(wan, &events);

    // Parity: the two planes' round/event logs must be byte-identical.
    let sim_log = records_json(&sim_recs).to_string();
    let ctl_log = records_json(&ctl_recs).to_string();
    assert_eq!(sim_log, ctl_log, "{name}: sim and controller event logs diverge");
    assert_rates_close(name, &sim_rates, &ctl_rates);

    // Golden: pin the simulator log (events + reactions + final rates).
    let doc = Json::from_pairs([
        ("topology", Json::from(name)),
        ("seed", SEED.into()),
        ("k", K.into()),
        ("horizon_s", HORIZON_S.into()),
        ("events", records_json(&sim_recs)),
        ("final_rates", rates_json(&sim_rates)),
    ]);
    let current = format!("{doc}\n");
    let path = golden_path(name);
    let bless = std::env::var("TERRA_BLESS").map(|v| v == "1").unwrap_or(false);
    match std::fs::read_to_string(&path) {
        Ok(golden) if !bless => {
            assert_eq!(
                golden,
                current,
                "{name}: scenario log changed vs {}; rerun with TERRA_BLESS=1 if intentional",
                path.display()
            );
        }
        _ => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("create tests/golden");
            std::fs::write(&path, &current).expect("write golden");
            eprintln!("blessed {}", path.display());
        }
    }
}

#[test]
fn golden_scenario_swan() {
    run_scenario("swan", topologies::swan());
}

#[test]
fn golden_scenario_gscale() {
    run_scenario("gscale", topologies::gscale());
}

#[test]
fn golden_scenario_att() {
    run_scenario("att", topologies::att());
}

/// Gray failures on SWAN: a pure never-down churn stream, pinned like the
/// other goldens (the CI bless-guard fails the job if this file
/// re-blesses). No structural events by design — the pathology is that
/// every link looks healthy.
#[test]
fn golden_scenario_swan_gray() {
    let wan = topologies::swan();
    let events = gray_events(&wan);
    assert!(
        events.iter().all(|e| matches!(e.ev, LinkEvent::SetBandwidth(..))),
        "gray scenario must stay structurally healthy"
    );
    run_scenario_events("swan_gray", wan, events);
}
