//! End-to-end overlay testbed tests: controller + one agent per datacenter
//! over loopback TCP, real bytes, token-bucket rate enforcement, in-order
//! reassembly, completion reporting, WAN-event reaction.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use terra::api::{TerraClient, REJECTED};
use terra::coflow::ServiceClass;
use terra::net::{topologies, LinkEvent};
use terra::overlay::protocol::{DataHeader, FlowSpec};
use terra::overlay::{Agent, Controller, TestbedConfig, BYTES_PER_GBPS};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};

struct Testbed {
    handle: terra::overlay::ControllerHandle,
    agents: Vec<Agent>,
}

fn start_testbed(wan: terra::net::Wan, k: usize) -> Testbed {
    let n = wan.num_nodes();
    let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, k, ..Default::default() });
    let handle = Controller::spawn(TestbedConfig::new(wan, k), Box::new(policy)).unwrap();
    let agents: Vec<Agent> = (0..n).map(|dc| Agent::spawn(dc, handle.addr).unwrap()).collect();
    assert!(handle.wait_ready(n, Duration::from_secs(10)), "agents failed to register");
    Testbed { handle, agents }
}

impl Testbed {
    fn stop(self) {
        for a in self.agents {
            a.shutdown();
        }
        self.handle.shutdown();
    }
}

/// 1 emulated Gbit as testbed bytes.
fn gbit(x: f64) -> u64 {
    (x * BYTES_PER_GBPS) as u64
}

#[test]
fn transfer_completes_and_is_in_order() {
    let tb = start_testbed(topologies::fig1a(), 3);
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    // 4 "Gbit" A(0) -> B(1): two 10 Gbps paths => ~0.2 s at full rate.
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(4.0) }];
    let cid = client.submit_coflow(&flows, None).unwrap();
    assert!(cid > 0);
    let cct = client.wait_done(cid as u64, 15.0).unwrap();
    assert!(cct > 0.05 && cct < 10.0, "cct={cct}");
    // Receiver saw every byte (in-order frontier reached the total).
    let received = tb.agents[1].received_bytes(cid as u64, 0);
    assert!(received >= gbit(4.0), "received={received}");
    tb.stop();
}

#[test]
fn multipath_beats_single_link_rate() {
    let tb = start_testbed(topologies::fig1a(), 3);
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    // 6 "Gbit" with both paths available: sustained rate should exceed one
    // 10 Gbps link's worth.
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(6.0) }];
    let t0 = Instant::now();
    let cid = client.submit_coflow(&flows, None).unwrap();
    let cct = client.wait_done(cid as u64, 20.0).unwrap();
    let _elapsed = t0.elapsed();
    // Single path at 10 Gbps would need 0.6 s; multipath should be faster
    // (allow generous margin for pacing granularity).
    assert!(cct < 0.55, "cct={cct} — multipath not engaged?");
    tb.stop();
}

#[test]
fn coflow_semantics_and_status() {
    let tb = start_testbed(topologies::fig1a(), 3);
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    // Two groups: A->B and C->B; coflow done only when both finish.
    let flows = [
        FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(2.0) },
        FlowSpec { id: 1, src_dc: 2, dst_dc: 1, bytes: gbit(4.0) },
    ];
    let cid = client.submit_coflow(&flows, None).unwrap() as u64;
    let cct = client.wait_done(cid, 20.0).unwrap();
    assert!(cct > 0.0);
    assert!(tb.agents[1].received_bytes(cid, 0) >= gbit(2.0));
    assert!(tb.agents[1].received_bytes(cid, 2) >= gbit(4.0));
    tb.stop();
}

#[test]
fn deadline_rejection_via_api() {
    let tb = start_testbed(topologies::fig1a(), 3);
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    // 100 "Gbit" over <= 20 Gbps takes >= 5 s; a 0.5 s deadline must be
    // rejected with cid = -1 (§5.2).
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(100.0) }];
    let cid = client.submit_coflow(&flows, Some(0.5)).unwrap();
    assert_eq!(cid, REJECTED);
    // A generous deadline admits. Terra *dilates* deadline coflows to
    // finish right at the deadline (§3.2 — finishing earlier has no
    // benefit), so expect completion at ~D plus feedback-loop lag (§6.4).
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(2.0) }];
    let cid = client.submit_coflow(&flows, Some(3.0)).unwrap();
    assert!(cid > 0);
    let cct = client.wait_done(cid as u64, 10.0).unwrap();
    assert!(cct <= 3.0 * 1.1 + 0.2, "admitted coflow missed deadline: {cct}");
    assert!(cct >= 2.0, "dilation should stretch the transfer: {cct}");
    tb.stop();
}

/// Service-class plumbing end-to-end: a stream submission carries its
/// floor over the wire, is admitted against headroom, and completes; a
/// floor the WAN cannot possibly cover is rejected at submission with the
/// same -1 sentinel deadlines use.
#[test]
fn stream_class_admission_via_api() {
    let tb = start_testbed(topologies::fig1a(), 3);
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(2.0) }];
    let cid = client
        .submit_coflow_class(&flows, None, &ServiceClass::Stream { rate_floor_gbps: 2.0 })
        .unwrap();
    assert!(cid > 0, "feasible stream must be admitted");
    let cct = client.wait_done(cid as u64, 15.0).unwrap();
    assert!(cct > 0.0);
    // No amount of multipathing gets 1000 Gbps out of fig1a: rejected.
    let cid = client
        .submit_coflow_class(&flows, None, &ServiceClass::Stream { rate_floor_gbps: 1000.0 })
        .unwrap();
    assert_eq!(cid, REJECTED);
    tb.stop();
}

#[test]
fn update_coflow_extends_transfer() {
    let tb = start_testbed(topologies::fig1a(), 3);
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(2.0) }];
    let cid = client.submit_coflow(&flows, None).unwrap() as u64;
    // Add more flows while (likely) still running (§5.2 updateCoflow).
    let extra = [FlowSpec { id: 1, src_dc: 2, dst_dc: 1, bytes: gbit(2.0) }];
    client.update_coflow(cid, &extra).unwrap();
    let _cct = client.wait_done(cid, 20.0).unwrap();
    assert!(tb.agents[1].received_bytes(cid, 2) >= gbit(2.0));
    tb.stop();
}

#[test]
fn reacts_to_link_failure() {
    let tb = start_testbed(topologies::fig1a(), 3);
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    // Long transfer A->B.
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(12.0) }];
    let cid = client.submit_coflow(&flows, None).unwrap() as u64;
    std::thread::sleep(Duration::from_millis(150));
    // Fail the direct link; Terra must reroute via C and still finish.
    client.wan_event(&LinkEvent::Fail(0, 1)).unwrap();
    let cct = client.wait_done(cid, 30.0).unwrap();
    assert!(cct > 0.0, "cct={cct}");
    // Rules were reinstalled on the structural event.
    let (max_rules, updates) = tb.handle.rule_stats();
    assert!(max_rules > 0);
    assert!(updates > 0);
    tb.stop();
}

/// Fuzz-ish hardening: garbage, truncated, and out-of-spec data frames on
/// an agent's data port must never panic a receive thread (a frame whose
/// `len` exceeded the chunk size used to index the reassembly buffer out
/// of bounds) — the agent drops the peer and keeps serving real traffic.
#[test]
fn malformed_data_frames_do_not_kill_agent() {
    // Count panics from *any* thread while the garbage is fed in; the
    // agent's receive threads swallow their own joins, so an assert on the
    // transfer alone would miss a panicked-but-restarted path.
    let panics = Arc::new(AtomicUsize::new(0));
    let observer = panics.clone();
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        observer.fetch_add(1, Ordering::Relaxed);
        prev(info);
    }));

    let tb = start_testbed(topologies::fig1a(), 3);
    let addr = tb.agents[1].data_addr;
    // Bad magic.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&[0u8; DataHeader::SIZE]).unwrap();
    }
    // Valid magic, absurd length (the former panic).
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let hdr = DataHeader { coflow: 1, src_dc: 0, offset: 0, len: u32::MAX };
        s.write_all(&hdr.encode()).unwrap();
        // Keep it open long enough for the reader to parse the header.
        std::thread::sleep(Duration::from_millis(50));
    }
    // Truncated header, then hangup.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&[0x01, 0xAA]).unwrap();
    }
    std::thread::sleep(Duration::from_millis(100));

    // The agent still serves a real transfer end-to-end.
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(2.0) }];
    let cid = client.submit_coflow(&flows, None).unwrap();
    assert!(cid > 0);
    let cct = client.wait_done(cid as u64, 15.0).unwrap();
    assert!(cct > 0.0);
    assert_eq!(
        panics.load(Ordering::Relaxed),
        0,
        "a background thread panicked on malformed input"
    );
    tb.stop();
}

#[test]
fn rules_do_not_change_during_scheduling() {
    let tb = start_testbed(topologies::fig1a(), 3);
    let before = tb.handle.rule_stats();
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    for i in 0..4u64 {
        let flows =
            [FlowSpec { id: 0, src_dc: 0, dst_dc: (i as usize % 2) + 1, bytes: gbit(0.5) }];
        let cid = client.submit_coflow(&flows, None).unwrap() as u64;
        client.wait_done(cid, 15.0).unwrap();
    }
    // Scheduling rounds, preemptions, and completions trigger zero rule
    // updates (§4.3) — only (re)initialization touches the rule table.
    assert_eq!(tb.handle.rule_stats(), before);
    tb.stop();
}
