//! Property tests for the service-class refactor's structural-inertness
//! guarantee: the default `ServiceClass::Batch` must leave every class-free
//! code path bit-identical (goldens stay blessed), and the level-1 floor
//! reservation must be exactly inert when no floors are present and
//! account for every reserved Gbps when they are.

use terra::coflow::ServiceClass;
use terra::lp::{maxmin, GroupDemand};
use terra::net::dynamics::{self, DynamicsModel, DynamicsProfile};
use terra::net::topologies;
use terra::scheduler::terra::TerraPolicy;
use terra::sim::{Job, SimConfig, Simulation};
use terra::util::prop::{forall, PropConfig};
use terra::util::rng::Pcg32;
use terra::workloads::{WorkloadGen, WorkloadKind};

/// Random batch job set (no explicit classes anywhere) plus a dynamics
/// stream seed for the SWAN topology.
fn gen_batch_case(rng: &mut Pcg32, size: usize) -> (Vec<Job>, u64) {
    let kind = WorkloadKind::all()[rng.below(4)];
    let mut wl = WorkloadGen::new(kind, rng.next_u64());
    let jobs = wl.jobs(&topologies::swan(), 1 + rng.below(size.max(1)));
    (jobs, rng.next_u64())
}

/// The tentpole inertness property: a simulation where every stage carries
/// the *structural default* class is bit-for-bit identical to one where
/// `ServiceClass::Batch` is written out explicitly, and none of the new
/// per-class metrics move off zero. This is the proof that un-re-blessed
/// golden traces remain valid: the class refactor added state, not
/// behavior, to the batch path.
#[test]
fn prop_batch_default_identical() {
    forall(
        PropConfig { cases: 8, seed: 0xC1A55, max_size: 4 },
        gen_batch_case,
        |(jobs, dseed)| {
            let wan = topologies::swan();
            let profile = DynamicsProfile {
                name: "prop".into(),
                models: vec![DynamicsModel::MarkovFailure { mtbf_s: 120.0, mttr_s: 6.0 }],
            };
            let events = dynamics::generate(&wan, &profile, 60.0, *dseed);
            let run = |jobs: Vec<Job>| {
                let mut sim = Simulation::new(
                    wan.clone(),
                    Box::new(TerraPolicy::default()),
                    SimConfig::default(),
                );
                for ev in &events {
                    sim.add_wan_event(ev.t, ev.ev.clone());
                }
                sim.run_jobs(jobs)
            };
            let implicit = run(jobs.clone());
            let explicit = run(
                jobs.iter()
                    .cloned()
                    .map(|mut j| {
                        for s in &mut j.stages {
                            s.class = ServiceClass::Batch;
                        }
                        j
                    })
                    .collect(),
            );
            if implicit.coflows.len() != explicit.coflows.len() {
                return Err(format!(
                    "coflow count diverged: {} vs {}",
                    implicit.coflows.len(),
                    explicit.coflows.len()
                ));
            }
            for (a, b) in implicit.coflows.iter().zip(&explicit.coflows) {
                if a.class != "batch" {
                    return Err(format!("coflow {} classed {:?}, not batch", a.id, a.class));
                }
                if a.finish.map(f64::to_bits) != b.finish.map(f64::to_bits) {
                    return Err(format!(
                        "coflow {} finish diverged: {:?} vs {:?}",
                        a.id, a.finish, b.finish
                    ));
                }
                if a.violation_s != 0.0 {
                    return Err(format!("batch coflow {} has violation_s {}", a.id, a.violation_s));
                }
            }
            if implicit.makespan.to_bits() != explicit.makespan.to_bits() {
                return Err(format!(
                    "makespan diverged: {} vs {}",
                    implicit.makespan, explicit.makespan
                ));
            }
            if implicit.rounds != explicit.rounds || implicit.lp_solves != explicit.lp_solves {
                return Err("round/solve counts diverged".into());
            }
            for rep in [&implicit, &explicit] {
                if rep.stream_violation_s != 0.0
                    || rep.tree_reshapes != 0
                    || rep.floor_shortfall_gbps != 0.0
                {
                    return Err(format!(
                        "class metrics nonzero on a batch-only run: {} / {} / {}",
                        rep.stream_violation_s, rep.tree_reshapes, rep.floor_shortfall_gbps
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Random MCF-shaped instance: capacities plus groups with random path
/// sets over those edges, and a floor vector where roughly half the groups
/// carry a floor.
#[allow(clippy::type_complexity)]
fn gen_floor_case(rng: &mut Pcg32, size: usize) -> (Vec<f64>, Vec<GroupDemand>, Vec<f64>) {
    let ne = 2 + rng.below(6);
    let cap: Vec<f64> = (0..ne).map(|_| rng.uniform(1.0, 20.0)).collect();
    let ng = 1 + rng.below(size.max(1) * 2);
    let groups: Vec<GroupDemand> = (0..ng)
        .map(|_| {
            let np = 1 + rng.below(3);
            let paths = (0..np)
                .map(|_| {
                    // Distinct edges per path (real paths are simple).
                    let len = 1 + rng.below(3.min(ne));
                    let mut es: Vec<usize> = (0..len).map(|_| rng.below(ne)).collect();
                    es.sort_unstable();
                    es.dedup();
                    es
                })
                .collect();
            GroupDemand { volume: rng.uniform(0.5, 50.0), paths }
        })
        .collect();
    let floors: Vec<f64> =
        (0..ng).map(|_| if rng.below(2) == 0 { 0.0 } else { rng.uniform(0.1, 8.0) }).collect();
    (cap, groups, floors)
}

/// Level-1 inertness: an all-zero floor vector must not move a single
/// capacity bit or produce any reservation, and the level-2 solve on the
/// "residual" must equal the plain solve exactly.
#[test]
fn prop_reserve_floors_zero_floor_inert() {
    forall(
        PropConfig { cases: 60, seed: 0xF100, max_size: 6 },
        gen_floor_case,
        |(cap, groups, _)| {
            let mut residual = cap.clone();
            let zeros = vec![0.0; groups.len()];
            let (reserved, shortfall) = maxmin::reserve_floors(&mut residual, groups, &zeros);
            for (e, (a, b)) in cap.iter().zip(&residual).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("edge {e} capacity moved: {a} -> {b}"));
                }
            }
            if reserved.iter().flatten().any(|&r| r != 0.0) {
                return Err("zero floors produced a reservation".into());
            }
            if shortfall.iter().any(|&s| s != 0.0) {
                return Err("zero floors produced a shortfall".into());
            }
            let weights = vec![1.0; groups.len()];
            let plain = maxmin::max_min_rates(cap, groups, &weights);
            let after = maxmin::max_min_rates(&residual, groups, &weights);
            for (k, (a, b)) in plain.iter().zip(&after).enumerate() {
                for (pa, pb) in a.iter().zip(b) {
                    if pa.to_bits() != pb.to_bits() {
                        return Err(format!("group {k} rates diverged: {pa} vs {pb}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Floor accounting: reservations never oversubscribe an edge, every
/// reserved Gbps is debited from exactly the edges its path crosses, and
/// `reserved + shortfall` covers each requested floor — infeasibility is
/// surfaced, never silently clamped away.
#[test]
fn prop_reserve_floors_accounting() {
    forall(
        PropConfig { cases: 80, seed: 0xF10, max_size: 6 },
        gen_floor_case,
        |(cap, groups, floors)| {
            let mut residual = cap.clone();
            let (reserved, shortfall) = maxmin::reserve_floors(&mut residual, groups, floors);
            // Per-edge debit equals the sum of reservations crossing it.
            let mut debit = vec![0.0; cap.len()];
            for (k, g) in groups.iter().enumerate() {
                for (pi, p) in g.paths.iter().enumerate() {
                    for &e in p {
                        debit[e] += reserved[k][pi];
                    }
                }
            }
            for (e, ((orig, res), d)) in cap.iter().zip(&residual).zip(&debit).enumerate() {
                if *res < -1e-12 || *res > orig + 1e-12 {
                    return Err(format!("edge {e} residual {res} outside [0, {orig}]"));
                }
                if (orig - res - d).abs() > 1e-6 {
                    return Err(format!(
                        "edge {e} conservation broken: {orig} - {res} != debit {d}"
                    ));
                }
            }
            // Every floor is either fully reserved or the gap is reported.
            for (k, g) in groups.iter().enumerate() {
                let floor = floors[k];
                let got: f64 = reserved[k].iter().sum();
                if floor <= 0.0 || g.volume <= 0.0 {
                    if got != 0.0 || shortfall[k] != 0.0 {
                        return Err(format!("floorless group {k} got {got}/{}", shortfall[k]));
                    }
                    continue;
                }
                if got > floor + 1e-9 {
                    return Err(format!("group {k} over-reserved: {got} > {floor}"));
                }
                if got + shortfall[k] < floor - 1e-6 {
                    return Err(format!(
                        "group {k} floor {floor} silently clamped: reserved {got} + \
                         shortfall {} leaves a gap",
                        shortfall[k]
                    ));
                }
            }
            Ok(())
        },
    );
}
