//! End-to-end telemetry over the real overlay: agents report achieved
//! per-path throughput, the controller fuses it into capacity beliefs and
//! probes stale edges — while an oracle-configured controller keeps
//! ignoring all of it.

use std::time::{Duration, Instant};
use terra::api::TerraClient;
use terra::net::telemetry::{EstimatorKind, TelemetryConfig};
use terra::net::topologies;
use terra::overlay::protocol::FlowSpec;
use terra::overlay::{Agent, Controller, TestbedConfig, BYTES_PER_GBPS};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};

struct Testbed {
    handle: terra::overlay::ControllerHandle,
    agents: Vec<Agent>,
}

fn start_testbed(wan: terra::net::Wan, k: usize, telemetry: TelemetryConfig) -> Testbed {
    let n = wan.num_nodes();
    let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, k, ..Default::default() });
    let handle = Controller::spawn(
        TestbedConfig::new(wan, k).with_telemetry(telemetry),
        Box::new(policy),
    )
    .unwrap();
    let agents: Vec<Agent> = (0..n).map(|dc| Agent::spawn(dc, handle.addr).unwrap()).collect();
    assert!(handle.wait_ready(n, Duration::from_secs(10)), "agents failed to register");
    Testbed { handle, agents }
}

impl Testbed {
    fn stop(self) {
        for a in self.agents {
            a.shutdown();
        }
        self.handle.shutdown();
    }
}

fn gbit(x: f64) -> u64 {
    (x * BYTES_PER_GBPS) as u64
}

/// Wait until `cond` holds or the deadline passes; returns whether it
/// held.
fn eventually(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    cond()
}

/// Belief mode on a real testbed: a transfer produces passive samples,
/// idle edges get probed, and beliefs stay physical (finite, within the
/// provisioned base capacity) despite loopback's absurd burst rates.
#[test]
fn telemetry_reports_flow_and_beliefs_stay_physical() {
    let telemetry = TelemetryConfig {
        estimator: EstimatorKind::Ewma { alpha: 0.3 },
        headroom_k: 0.0,
        sample_interval_s: 0.25,
        probe_after_s: 0.5,
    };
    let tb = start_testbed(topologies::fig1a(), 3, telemetry);
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    // Long enough (≈1 s at full believed rate) that several 250 ms
    // telemetry windows catch the transfer in flight.
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(20.0) }];
    let cid = client.submit_coflow(&flows, None).unwrap();
    assert!(cid > 0);
    let cct = client.wait_done(cid as u64, 60.0).unwrap();
    assert!(cct > 0.0);

    // Passive samples from the transfer, probes for the edges it never
    // touched.
    assert!(
        eventually(Duration::from_secs(10), || {
            let s = tb.handle.telemetry_stats();
            s.reports > 0 && s.samples > 0 && s.probes_sent > 0
        }),
        "telemetry never flowed: {:?}",
        tb.handle.telemetry_stats()
    );

    // Beliefs must stay within the physically provisioned envelope even
    // though loopback probe bursts "measure" hundreds of Gbps.
    let wan = topologies::fig1a();
    for l in wan.links() {
        let believed = tb.handle.believed_capacity(l.src, l.dst).unwrap();
        assert!(
            believed.is_finite() && believed >= 0.0 && believed <= l.base_capacity + 1e-6,
            "belief for {}->{} escaped the physical envelope: {believed}",
            l.src,
            l.dst
        );
    }
    tb.stop();
}

/// Oracle controllers count reports but fuse nothing and probe nothing —
/// the pre-telemetry behavior, bit for bit.
#[test]
fn oracle_controller_ignores_telemetry() {
    let tb = start_testbed(topologies::fig1a(), 3, TelemetryConfig::oracle());
    let mut client = TerraClient::connect(tb.handle.addr).unwrap();
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(2.0) }];
    let cid = client.submit_coflow(&flows, None).unwrap();
    client.wait_done(cid as u64, 60.0).unwrap();
    // Give the agents time to flush at least one report.
    assert!(
        eventually(Duration::from_secs(5), || tb.handle.telemetry_stats().reports > 0),
        "agents never reported"
    );
    let s = tb.handle.telemetry_stats();
    assert_eq!(s.samples, 0, "oracle must not fuse samples");
    assert_eq!(s.probes_sent, 0, "oracle must not probe");
    // Beliefs (= truth) untouched at base capacity.
    let wan = topologies::fig1a();
    for l in wan.links() {
        let believed = tb.handle.believed_capacity(l.src, l.dst).unwrap();
        assert!((believed - l.base_capacity).abs() < 1e-9);
    }
    tb.stop();
}
