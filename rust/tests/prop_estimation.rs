//! Property tests for the telemetry / capacity-estimation subsystem.
//!
//! The load-bearing invariant: **`Estimator::Oracle` is bit-identical to
//! the pre-telemetry engine** — same rounds, same allocations, same
//! epochs — on random dynamics streams over all three evaluation
//! topologies, even while the telemetry entry points are being spammed
//! (observations, probes, priors, and belief refreshes must all be inert
//! no-ops under the oracle). The committed golden traces pin the absolute
//! behavior; these properties pin the equivalence under churn.

use terra::coflow::{Coflow, Flow};
use terra::engine::{EngineConfig, RoundEngine};
use terra::net::dynamics::{self, DynamicsModel, DynamicsProfile};
use terra::net::telemetry::{EstimatorKind, TelemetryConfig};
use terra::net::{topologies, Wan};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::{CoflowState, RoundTrigger};
use terra::sim::{Job, SimConfig, Simulation};
use terra::util::rng::Pcg32;

fn eval_topologies() -> Vec<(&'static str, Wan)> {
    vec![("swan", topologies::swan()), ("gscale", topologies::gscale()), ("att", topologies::att())]
}

/// A dynamics mix that exercises every reaction class: diurnal sub-/super-ρ
/// fluctuations, structural fail/recover, and gray-failure churn.
fn mixed_profile() -> DynamicsProfile {
    DynamicsProfile {
        name: "mix".into(),
        models: vec![
            DynamicsModel::Diurnal { period_s: 60.0, amplitude: 0.5, jitter: 0.1, interval_s: 7.0 },
            DynamicsModel::MarkovFailure { mtbf_s: 120.0, mttr_s: 9.0 },
            DynamicsModel::GrayFailure {
                mtbg_s: 90.0,
                episode_s: 20.0,
                low_frac: 0.2,
                churn_interval_s: 5.0,
                churn_amp: 0.4,
            },
        ],
    }
}

fn mk_engine(wan: Wan, telemetry: TelemetryConfig) -> RoundEngine {
    let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, k: 3, ..Default::default() });
    RoundEngine::new(
        wan,
        Box::new(policy),
        EngineConfig { check_feasibility: true, telemetry, ..Default::default() },
    )
}

fn random_coflow(id: u64, nodes: usize, rng: &mut Pcg32) -> CoflowState {
    let s = rng.below(nodes);
    let mut d = rng.below(nodes);
    while d == s {
        d = rng.below(nodes);
    }
    let mut st = CoflowState::from_coflow(&Coflow::new(
        id,
        vec![Flow { id: 0, src_dc: s, dst_dc: d, volume: rng.uniform(50.0, 400.0) }],
    ));
    st.admitted = true;
    st
}

/// Oracle engines stepped in lockstep over random event streams, one of
/// them spammed with telemetry between every event: rounds, epochs, and
/// allocations must stay bit-identical throughout.
#[test]
fn prop_oracle_bit_identical_under_telemetry_spam() {
    for (tname, wan) in eval_topologies() {
        for seed in 0..3u64 {
            let events = dynamics::generate(&wan, &mixed_profile(), 90.0, seed);
            assert!(!events.is_empty(), "{tname}: empty stream");
            let plain = TelemetryConfig::oracle();
            // Oracle with aggressive telemetry knobs: all of it must be
            // inert.
            let noisy = TelemetryConfig {
                estimator: EstimatorKind::Oracle,
                headroom_k: 3.0,
                sample_interval_s: 0.05,
                probe_after_s: 0.1,
            };
            let mut a = mk_engine(wan.clone(), plain);
            let mut b = mk_engine(wan.clone(), noisy);
            let mut rng = Pcg32::new(seed ^ 0x7E11E);
            let mut next_id = 1u64;
            let num_edges = wan.num_edges();
            for (i, ev) in events.iter().enumerate().take(60) {
                if i % 6 == 0 {
                    let st = random_coflow(next_id, wan.num_nodes(), &mut rng);
                    next_id += 1;
                    for e in [&mut a, &mut b] {
                        e.insert(st.clone());
                        e.round(ev.t, RoundTrigger::CoflowArrival);
                    }
                }
                // Spam engine B's telemetry surface before the event...
                b.observe_edge(i % num_edges, rng.uniform(0.1, 50.0), i % 2 == 0, ev.t);
                b.probe_edge((i * 3) % num_edges, rng.uniform(0.1, 50.0), ev.t);
                b.announce_prior((i * 5) % num_edges, rng.uniform(0.1, 50.0), ev.t, ev.t + 1.0);
                assert_eq!(b.refresh_beliefs(), None, "{tname}: oracle refresh must be None");
                // ...then deliver the same truth event to both.
                let (ra, rb) = (a.handle_wan_event(&ev.ev), b.handle_wan_event(&ev.ev));
                assert_eq!(ra, rb, "{tname} seed {seed} event {i}: reactions diverged");
                if let Some(t) = ra.trigger() {
                    a.round(ev.t, t);
                    b.round(ev.t, t);
                }
                assert_eq!(a.epoch(), b.epoch(), "{tname} seed {seed} event {i}: epochs");
                assert_eq!(
                    a.alloc().rates,
                    b.alloc().rates,
                    "{tname} seed {seed} event {i}: allocations diverged"
                );
                for e in [&mut a, &mut b] {
                    e.drain(0.05, 0.0);
                    e.take_finished();
                }
            }
            assert_eq!(a.rounds(), b.rounds(), "{tname} seed {seed}: round counts");
        }
    }
}

/// Whole-simulation equivalence: a default sim and an explicit-oracle sim
/// (with telemetry knobs set) over random dynamics streams produce
/// bit-identical reports — rounds, LP solves, CCTs, epochs.
#[test]
fn prop_oracle_sim_reports_bit_identical() {
    for (tname, wan) in eval_topologies() {
        for seed in 0..2u64 {
            let events = dynamics::generate(&wan, &mixed_profile(), 60.0, seed ^ 0xA5);
            let run = |telemetry: TelemetryConfig| {
                let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
                let mut sim = Simulation::new(
                    wan.clone(),
                    Box::new(policy),
                    SimConfig { telemetry, ..Default::default() },
                );
                let mut rng = Pcg32::new(seed ^ 0xBEEF);
                for id in 0..4u64 {
                    let nodes = wan.num_nodes();
                    let s = rng.below(nodes);
                    let mut d = rng.below(nodes);
                    while d == s {
                        d = rng.below(nodes);
                    }
                    sim.add_job(Job::map_reduce(
                        id + 1,
                        rng.uniform(0.0, 5.0),
                        0.0,
                        vec![Flow { id: 0, src_dc: s, dst_dc: d, volume: rng.uniform(20.0, 120.0) }],
                    ));
                }
                for ev in &events {
                    sim.add_wan_event(ev.t, ev.ev.clone());
                }
                sim.run()
            };
            let a = run(TelemetryConfig::oracle());
            let b = run(TelemetryConfig {
                estimator: EstimatorKind::Oracle,
                headroom_k: 2.0,
                sample_interval_s: 0.1,
                probe_after_s: 0.5,
            });
            assert_eq!(a.rounds, b.rounds, "{tname} seed {seed}");
            assert_eq!(a.lp_solves, b.lp_solves, "{tname} seed {seed}");
            assert_eq!(a.wan_rounds, b.wan_rounds, "{tname} seed {seed}");
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{tname} seed {seed}");
            assert_eq!(a.est_samples, 0);
            assert_eq!(b.est_samples, 0, "oracle sims must not sample");
            for (ca, cb) in a.coflows.iter().zip(&b.coflows) {
                assert_eq!(
                    ca.finish.map(f64::to_bits),
                    cb.finish.map(f64::to_bits),
                    "{tname} seed {seed}: CCT diverged"
                );
            }
        }
    }
}

/// Feasibility under estimation: whatever the estimator believes, the
/// engine's allocation is always feasible on the *believed* WAN, and the
/// truth-throttled drain keeps goodput within true capacity. Run a
/// belief-mode sim over an adversarial gray stream and check it converges
/// and completes.
#[test]
fn prop_belief_mode_survives_gray_stream() {
    let wan = topologies::swan();
    for (ename, seed) in
        [("ewma", 1u64), ("kalman", 2), ("holddown", 3), ("ewma", 4), ("kalman", 5)]
    {
        let events = dynamics::generate(&wan, &DynamicsProfile::gray(), 120.0, seed);
        let telemetry = TelemetryConfig {
            sample_interval_s: 0.5,
            probe_after_s: 3.0,
            ..TelemetryConfig::by_name(ename).unwrap()
        };
        let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        let mut sim = Simulation::new(
            wan.clone(),
            Box::new(policy),
            SimConfig { telemetry, ..Default::default() },
        );
        sim.add_job(Job::map_reduce(
            1,
            0.0,
            0.0,
            vec![Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 200.0 }],
        ));
        sim.add_job(Job::map_reduce(
            2,
            1.0,
            0.0,
            vec![Flow { id: 0, src_dc: 2, dst_dc: 3, volume: 150.0 }],
        ));
        for ev in &events {
            sim.add_wan_event(ev.t, ev.ev.clone());
        }
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0, "{ename} seed {seed}: starved under gray churn");
        assert!(rep.est_mape().is_finite(), "{ename} seed {seed}");
        assert!(
            rep.makespan < 5000.0,
            "{ename} seed {seed}: estimation stalled the workload ({}s)",
            rep.makespan
        );
    }
}
