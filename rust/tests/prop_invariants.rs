//! Property-based tests (via the in-tree `util::prop` harness) for the
//! coordinator's core invariants: capacity feasibility, equal progress,
//! Lemma 3.1, solver agreement, scheduler dominance, simulator
//! conservation, and admission safety.

use terra::coflow::{coalesce, Coflow, Flow};
use terra::engine::{EngineConfig, RoundEngine, WanReaction};
use terra::lp::{self, GroupDemand, McfInstance, SolverKind};
use terra::net::dynamics::{self, DynamicsModel, DynamicsProfile};
use terra::net::paths::PathSet;
use terra::net::topologies;
use terra::net::{LinkEvent, Wan};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::{Allocation, CoflowState, NetView, Policy, RoundTrigger};
use terra::sim::{Job, SimConfig, Simulation};
use terra::util::prop::{forall, PropConfig};
use terra::util::rng::Pcg32;

/// Random coflow set on the SWAN topology.
fn gen_coflows(rng: &mut Pcg32, size: usize) -> Vec<Coflow> {
    let n = 5;
    let num = 1 + rng.below(size.max(1));
    (0..num)
        .map(|i| {
            let flows = (0..1 + rng.below(6))
                .map(|f| {
                    let s = rng.below(n);
                    let mut d = rng.below(n);
                    while d == s {
                        d = rng.below(n);
                    }
                    Flow { id: f as u64, src_dc: s, dst_dc: d, volume: rng.uniform(1.0, 200.0) }
                })
                .collect();
            Coflow::new(i as u64 + 1, flows)
        })
        .collect()
}

/// Random composition of all three generative dynamics models with random
/// parameters, plus a random coflow population and a stream seed.
fn gen_dynamics_case(rng: &mut Pcg32, size: usize) -> (Vec<Coflow>, DynamicsProfile, u64) {
    let coflows = gen_coflows(rng, size);
    let profile = DynamicsProfile {
        name: "prop".into(),
        models: vec![
            DynamicsModel::Diurnal {
                period_s: rng.uniform(20.0, 90.0),
                amplitude: rng.uniform(0.1, 0.6),
                jitter: rng.uniform(0.0, 0.1),
                interval_s: rng.uniform(2.0, 8.0),
            },
            DynamicsModel::MarkovFailure {
                mtbf_s: rng.uniform(80.0, 400.0),
                mttr_s: rng.uniform(4.0, 15.0),
            },
            DynamicsModel::RegionalOutage {
                mtbo_s: rng.uniform(80.0, 400.0),
                outage_s: rng.uniform(4.0, 12.0),
            },
        ],
    };
    (coflows, profile, rng.next_u64())
}

/// Replay a generated dynamics stream through a `RoundEngine` on SWAN,
/// invoking `check` after every `handle_wan_event` (before the follow-up
/// round, when one is due). Rounds run with feasibility assertions on.
fn replay_with_dynamics(
    coflows: &[Coflow],
    profile: &DynamicsProfile,
    seed: u64,
    mut check: impl FnMut(&RoundEngine, &LinkEvent, WanReaction, u64) -> Result<(), String>,
) -> Result<(), String> {
    let wan = topologies::swan();
    let events = dynamics::generate(&wan, profile, 15.0, seed);
    let mut engine = RoundEngine::new(
        wan,
        Box::new(TerraPolicy::new(TerraConfig { k: 5, ..Default::default() })),
        EngineConfig { check_feasibility: true, ..Default::default() },
    );
    for c in coflows {
        engine.insert(CoflowState::from_coflow(c));
    }
    engine.round(0.0, RoundTrigger::Initial);
    for ev in &events {
        let epoch_before = engine.epoch();
        let reaction = engine.handle_wan_event(&ev.ev);
        check(&engine, &ev.ev, reaction, epoch_before)?;
        if reaction.trigger().is_some() {
            engine.round(ev.t, RoundTrigger::WanChange);
        }
    }
    Ok(())
}

#[test]
fn prop_clamped_allocations_stay_feasible_under_dynamics() {
    // Sub-ρ events clamp instead of re-optimizing: the clamped allocation
    // must remain feasible on the *shrunk* WAN after every such event, for
    // arbitrary seeded dynamics streams.
    forall(
        PropConfig { cases: 10, seed: 0xD1A, max_size: 4 },
        gen_dynamics_case,
        |(coflows, profile, seed)| {
            replay_with_dynamics(coflows, profile, *seed, |engine, ev, reaction, _| {
                if reaction != WanReaction::Clamped {
                    return Ok(());
                }
                let net = NetView { wan: engine.wan(), paths: engine.paths() };
                let usage =
                    engine.alloc().edge_usage(engine.active(), &net, engine.wan().num_edges());
                for (e, (u, c)) in usage.iter().zip(engine.wan().capacities()).enumerate() {
                    if *u > c * (1.0 + 1e-4) + 1e-6 {
                        return Err(format!(
                            "edge {e} oversubscribed after clamping {ev:?}: {u} > {c}"
                        ));
                    }
                }
                Ok(())
            })
        },
    );
}

#[test]
fn prop_capacity_epoch_is_monotonic() {
    // The Γ-cache capacity epoch never regresses, advances by exactly one
    // on every qualifying event, and holds still across clamps.
    forall(
        PropConfig { cases: 10, seed: 0xE9, max_size: 4 },
        gen_dynamics_case,
        |(coflows, profile, seed)| {
            replay_with_dynamics(coflows, profile, *seed, |engine, ev, reaction, before| {
                let after = engine.epoch();
                if after < before {
                    return Err(format!("epoch regressed {before} -> {after} on {ev:?}"));
                }
                match reaction {
                    WanReaction::Structural | WanReaction::Reoptimize if after != before + 1 => {
                        Err(format!("{reaction:?} on {ev:?} must bump epoch: {before} -> {after}"))
                    }
                    WanReaction::Clamped if after != before => {
                        Err(format!("clamp on {ev:?} must keep the epoch: {before} -> {after}"))
                    }
                    _ => Ok(()),
                }
            })
        },
    );
}

#[test]
fn prop_accumulated_sub_rho_drift_always_triggers_a_round() {
    // Individually ignorable fluctuations must not be collectively
    // ignorable: whenever the engine answers `Clamped` (no round), no
    // edge's available capacity may have drifted ≥ ρ from that edge's own
    // baseline — re-anchored when the edge itself qualified (its
    // components re-solved) or at a structural event (everything
    // re-solved). Equivalently, accumulated drift ≥ ρ always comes back
    // as a round-triggering reaction.
    let rho = terra::scheduler::DEFAULT_RHO;
    forall(
        PropConfig { cases: 10, seed: 0xD21F7, max_size: 4 },
        gen_dynamics_case,
        |(coflows, profile, seed)| {
            // The engine anchors its drift baselines on the capacities at
            // construction; mirror that starting point exactly.
            let mut snapshot: Vec<f64> = topologies::swan().capacities();
            replay_with_dynamics(coflows, profile, *seed, |engine, ev, reaction, _| {
                let caps = engine.wan().capacities();
                match reaction {
                    // Structural: paths recomputed, every component
                    // re-solves — every baseline re-anchors.
                    WanReaction::Structural => {
                        snapshot = caps;
                        return Ok(());
                    }
                    // Qualifying fluctuation: only the touched edge's
                    // components re-solve, so only its baseline moves.
                    WanReaction::Reoptimize => {
                        if let LinkEvent::SetBandwidth(u, v, _) = *ev {
                            if let Some(e) = engine.wan().edge_between(u, v) {
                                snapshot[e] = caps[e];
                            }
                        }
                        return Ok(());
                    }
                    WanReaction::Clamped => {}
                }
                for (e, (c, c0)) in caps.iter().zip(snapshot.iter()).enumerate() {
                    let dev = (c - c0).abs() / c0.max(1e-9);
                    if dev >= rho {
                        return Err(format!(
                            "edge {e} drifted {dev:.3} >= rho since its last re-solve, yet \
                             {ev:?} was only clamped"
                        ));
                    }
                }
                Ok(())
            })
        },
    );
}

/// One engine round over `coflows` with decomposition on/off. Feasibility
/// is asserted inside the engine (`check_feasibility: true`), so both the
/// monolithic allocation and the union of the component allocations are
/// link-feasible by construction of the test. Returns the allocation, the
/// active states, and how many components were solved.
fn one_round(
    wan: &Wan,
    coflows: &[Coflow],
    k: usize,
    decompose: bool,
) -> (Allocation, Vec<CoflowState>, usize) {
    let mut e = RoundEngine::new(
        wan.clone(),
        Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, k, ..Default::default() })),
        EngineConfig { check_feasibility: true, decompose, ..Default::default() },
    );
    for c in coflows {
        e.insert(CoflowState::from_coflow(c));
    }
    e.round(0.0, RoundTrigger::Initial);
    let solves = e.take_stats().component_solves;
    (e.alloc().clone(), e.active().to_vec(), solves)
}

/// Per-group total rates of two allocations must agree within
/// `rel`-relative + `abs`-absolute tolerance, and cover the same coflows.
fn rates_close(
    mono: &Allocation,
    comp: &Allocation,
    states: &[CoflowState],
    rel: f64,
    abs: f64,
) -> Result<(), String> {
    for st in states {
        let (a, b) = (mono.rates.get(&st.id), comp.rates.get(&st.id));
        if a.is_some() != b.is_some() {
            return Err(format!(
                "coflow {}: allocation presence differs (mono {:?}, comp {:?})",
                st.id,
                a.is_some(),
                b.is_some()
            ));
        }
        let (Some(a), Some(b)) = (a, b) else { continue };
        for gi in 0..st.groups.len() {
            let ga: f64 = a.get(gi).map(|v| v.iter().sum()).unwrap_or(0.0);
            let gb: f64 = b.get(gi).map(|v| v.iter().sum()).unwrap_or(0.0);
            if (ga - gb).abs() > rel * ga.max(gb) + abs {
                return Err(format!(
                    "coflow {} group {gi}: monolithic rate {ga} vs decomposed {gb}",
                    st.id
                ));
            }
        }
    }
    Ok(())
}

/// Tentpole invariant: component-decomposed rounds are allocation-
/// equivalent to the monolithic solve. On a realistic topology the random
/// sets usually collapse into one component — in which case the decomposed
/// solve sees the identical subset and must match the monolithic result
/// **exactly**; genuinely split cases match within tolerance (only the
/// best-effort work-conservation pass is approximate across the split).
#[test]
fn prop_component_decomposition_equivalent_on_swan() {
    let wan = topologies::swan();
    forall(
        PropConfig { cases: 12, seed: 0xC0117, max_size: 6 },
        gen_coflows,
        |coflows| {
            let (mono, states, _) = one_round(&wan, coflows, 5, false);
            let (comp, _, solves) = one_round(&wan, coflows, 5, true);
            if solves <= 1 {
                if mono.rates != comp.rates {
                    return Err("single-component decomposition must be bit-identical".into());
                }
                return Ok(());
            }
            rates_close(&mono, &comp, &states, 0.25, 2.0)
        },
    );
}

/// The genuinely-split case, pinned: two edge-disjoint triangles, coflows
/// confined to one triangle each. The sequential min-CCT phase decomposes
/// exactly (GK's measure is restricted to instance-relevant edges); the
/// work-conservation max-min runs to completion at these sizes, so
/// per-group rates agree tightly — and with coflows in both triangles the
/// engine must actually have solved ≥ 2 components.
#[test]
fn prop_component_decomposition_exact_on_disjoint_clusters() {
    let wan = {
        let mut w = Wan::new();
        for i in 0..6 {
            w.add_node(&format!("N{i}"), 0.0, i as f64);
        }
        for (u, v) in [(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)] {
            w.add_link(u, v, 10.0, Some(1.0));
        }
        w
    };
    forall(
        PropConfig { cases: 20, seed: 0x2C1A5, max_size: 4 },
        |rng, size| {
            let clusters = [[0usize, 1, 2], [3, 4, 5]];
            let num = 1 + rng.below(size.max(1));
            (0..num)
                .map(|i| {
                    let cl = clusters[rng.below(2)];
                    let flows = (0..1 + rng.below(2))
                        .map(|f| {
                            let s = cl[rng.below(3)];
                            let mut d = cl[rng.below(3)];
                            while d == s {
                                d = cl[rng.below(3)];
                            }
                            Flow {
                                id: f as u64,
                                src_dc: s,
                                dst_dc: d,
                                volume: rng.uniform(1.0, 100.0),
                            }
                        })
                        .collect();
                    Coflow::new(i as u64 + 1, flows)
                })
                .collect::<Vec<_>>()
        },
        |coflows| {
            let (mono, states, _) = one_round(&wan, coflows, 3, false);
            let (comp, _, solves) = one_round(&wan, coflows, 3, true);
            let mut used: Vec<usize> =
                coflows.iter().flat_map(|c| c.flows.iter().map(|f| f.src_dc / 3)).collect();
            used.sort_unstable();
            used.dedup();
            if solves < used.len() {
                return Err(format!(
                    "expected ≥ {} components (one per occupied triangle), solved {solves}",
                    used.len()
                ));
            }
            rates_close(&mono, &comp, &states, 0.15, 1.0)
        },
    );
}

#[test]
fn prop_allocations_always_feasible_and_conserving() {
    let wan = topologies::swan();
    let paths = PathSet::compute(&wan, 15);
    forall(
        PropConfig { cases: 60, seed: 0xA11, max_size: 8 },
        gen_coflows,
        |coflows| {
            let states: Vec<CoflowState> =
                coflows.iter().map(CoflowState::from_coflow).collect();
            let mut policy = TerraPolicy::default();
            let net = NetView { wan: &wan, paths: &paths };
            let alloc = policy.allocate(0.0, RoundTrigger::Initial, &states, &net);
            // Capacity feasibility on every edge.
            let usage = alloc.edge_usage(&states, &net, wan.num_edges());
            for (e, (u, c)) in usage.iter().zip(wan.capacities()).enumerate() {
                if *u > c * (1.0 + 1e-4) + 1e-6 {
                    return Err(format!("edge {e} oversubscribed: {u} > {c}"));
                }
            }
            // No rate assigned to nonexistent paths; all rates nonnegative.
            for st in &states {
                if let Some(rates) = alloc.rates.get(&st.id) {
                    for (gi, g) in st.groups.iter().enumerate() {
                        let np = paths.get(g.src, g.dst).len();
                        if rates[gi].len() > np {
                            return Err(format!("more rates than paths for {gi}"));
                        }
                        if rates[gi].iter().any(|r| *r < -1e-9) {
                            return Err("negative rate".into());
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lemma31_grouping_preserves_cct() {
    // Lemma 3.1: splitting a FlowGroup's volume across its constituent
    // flows in ANY work-conserving way leaves the group completion time
    // unchanged — i.e. the LP's λ depends only on the per-pair totals.
    let wan = topologies::swan();
    let paths = PathSet::compute(&wan, 15);
    forall(
        PropConfig { cases: 40, seed: 0x31, max_size: 6 },
        |rng, size| {
            let mut flows = Vec::new();
            for f in 0..1 + rng.below(size.max(1)) {
                let s = rng.below(5);
                let mut d = rng.below(5);
                while d == s {
                    d = rng.below(5);
                }
                flows.push(Flow {
                    id: f as u64,
                    src_dc: s,
                    dst_dc: d,
                    volume: rng.uniform(1.0, 100.0),
                });
            }
            // A random re-split of the same totals into more flows.
            let mut resplit = Vec::new();
            let mut id = 0;
            for fl in &flows {
                let parts = 1 + rng.below(4);
                for _ in 0..parts {
                    resplit.push(Flow {
                        id,
                        src_dc: fl.src_dc,
                        dst_dc: fl.dst_dc,
                        volume: fl.volume / parts as f64,
                    });
                    id += 1;
                }
            }
            (flows, resplit)
        },
        |(flows, resplit)| {
            let inst = |fs: &[Flow]| {
                let groups = coalesce(fs)
                    .into_iter()
                    .map(|g| GroupDemand {
                        volume: g.volume,
                        paths: paths.get(g.src, g.dst).iter().map(|p| p.edges.clone()).collect(),
                    })
                    .collect();
                McfInstance { cap: wan.capacities(), groups }
            };
            let a = lp::max_concurrent(&inst(flows), SolverKind::Simplex)
                .ok_or("infeasible a")?;
            let b = lp::max_concurrent(&inst(resplit), SolverKind::Simplex)
                .ok_or("infeasible b")?;
            terra::util::prop::close(a.lambda, b.lambda, 1e-6)
        },
    );
}

#[test]
fn prop_gk_close_to_simplex() {
    let wan = topologies::swan();
    let paths = PathSet::compute(&wan, 15);
    forall(
        PropConfig { cases: 40, seed: 0x6B, max_size: 6 },
        gen_coflows,
        |coflows| {
            let groups: Vec<GroupDemand> = coflows
                .iter()
                .flat_map(|c| c.flow_groups())
                .map(|g| GroupDemand {
                    volume: g.volume,
                    paths: paths.get(g.src, g.dst).iter().map(|p| p.edges.clone()).collect(),
                })
                .collect();
            if groups.is_empty() {
                return Ok(());
            }
            let inst = McfInstance { cap: wan.capacities(), groups };
            let sx = lp::max_concurrent(&inst, SolverKind::Simplex).ok_or("simplex failed")?;
            let gk = lp::max_concurrent(&inst, SolverKind::Gk).ok_or("gk failed")?;
            inst.check(&gk, 1e-6).map_err(|e| e.to_string())?;
            if gk.lambda < 0.85 * sx.lambda || gk.lambda > sx.lambda * (1.0 + 1e-6) {
                return Err(format!("gk {} vs simplex {}", gk.lambda, sx.lambda));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simulator_conserves_bytes() {
    let wan = topologies::swan();
    forall(
        PropConfig { cases: 25, seed: 0x51AD, max_size: 6 },
        |rng, size| {
            let coflows = gen_coflows(rng, size);
            coflows
                .into_iter()
                .enumerate()
                .map(|(i, c)| Job::map_reduce(i as u64, rng.uniform(0.0, 30.0), 0.0, c.flows))
                .collect::<Vec<_>>()
        },
        |jobs| {
            let expected: f64 = jobs.iter().map(|j| j.total_volume()).sum();
            let mut sim = Simulation::new(
                wan.clone(),
                Box::new(TerraPolicy::default()),
                SimConfig::default(),
            );
            let rep = sim.run_jobs(jobs.clone());
            if rep.unfinished() > 0 {
                return Err("unfinished coflows on a healthy WAN".into());
            }
            terra::util::prop::close(rep.transferred_gbit, expected, 1e-6)
        },
    );
}

#[test]
fn prop_terra_no_worse_than_fifo_order() {
    // SRTF-style ordering should beat (or match) arrival-order scheduling
    // on average CCT for same-time arrivals.
    let wan = topologies::swan();
    forall(
        PropConfig { cases: 15, seed: 0xF1F0, max_size: 5 },
        gen_coflows,
        |coflows| {
            let jobs: Vec<Job> = coflows
                .iter()
                .enumerate()
                .map(|(i, c)| Job::map_reduce(i as u64, 0.0, 0.0, c.flows.clone()))
                .collect();
            let mut terra_sim = Simulation::new(
                wan.clone(),
                Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() })),
                SimConfig::default(),
            );
            let t = terra_sim.run_jobs(jobs.clone());
            let mut fair_sim = Simulation::new(
                wan.clone(),
                terra::baselines::by_name("per-flow").unwrap(),
                SimConfig::default(),
            );
            let f = fair_sim.run_jobs(jobs);
            // Allow a small tolerance: per-flow can win tiny instances by
            // luck of the GK approximation.
            if t.avg_cct() > f.avg_cct() * 1.12 + 0.5 {
                return Err(format!("terra {} vs per-flow {}", t.avg_cct(), f.avg_cct()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_admission_is_sound_in_static_network() {
    // Any coflow admitted alone on an idle WAN must be able to meet its
    // deadline (η = 1): Γ ≤ D at admission implies completion ≤ D.
    let wan = topologies::swan();
    forall(
        PropConfig { cases: 30, seed: 0xADA, max_size: 5 },
        |rng, size| {
            let c = gen_coflows(rng, size).remove(0);
            let d = rng.uniform(1.0, 120.0);
            (c, d)
        },
        |(c, d)| {
            let mut job = Job::map_reduce(1, 0.0, 0.0, c.flows.clone());
            job.stages[0].deadline = Some(*d);
            let mut sim = Simulation::new(
                wan.clone(),
                Box::new(TerraPolicy::default()),
                SimConfig::default(),
            );
            let rep = sim.run_jobs(vec![job]);
            let rec = &rep.coflows[0];
            if rec.admitted {
                if !rec.met_deadline() {
                    return Err(format!(
                        "admitted but missed: cct {:?} deadline {:?}",
                        rec.cct(),
                        rec.deadline
                    ));
                }
            } else {
                // Rejected => the deadline was genuinely tight: min CCT > d.
                if rec.min_cct <= *d * 0.9 {
                    return Err(format!(
                        "rejected although min_cct {} << d {}",
                        rec.min_cct, d
                    ));
                }
            }
            Ok(())
        },
    );
}
