//! Determinism properties of the sharded control plane:
//!
//! 1. `shards = N` is bit-identical to `shards = 1` — same allocations,
//!    same solve counts — through multi-round runs with arrivals, drains,
//!    bandwidth changes, and structural link failures (which force a full
//!    cross-shard redistribution). Sharding is an execution strategy, not
//!    a policy change.
//! 2. The incrementally maintained edge-connected partition is equivalent
//!    to a from-scratch decomposition after every round, including rounds
//!    that reused it unchanged.

use terra::coflow::{Coflow, CoflowId, Flow, GB};
use terra::engine::{EngineConfig, RoundEngine, ShardedEngine, WanReaction};
use terra::lp::decompose;
use terra::net::{EdgeId, LinkEvent, Wan};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::{CoflowState, RoundTrigger};

/// Two edge-disjoint triangles (N0–N2, N3–N5): the natural two-component
/// topology, so a multi-shard engine actually spreads work.
fn two_triangles() -> Wan {
    let mut w = Wan::new();
    for i in 0..6 {
        w.add_node(&format!("N{i}"), 0.0, i as f64);
    }
    w.add_link(0, 1, 10.0, Some(1.0));
    w.add_link(1, 2, 10.0, Some(1.0));
    w.add_link(0, 2, 10.0, Some(1.0));
    w.add_link(3, 4, 10.0, Some(1.0));
    w.add_link(4, 5, 10.0, Some(1.0));
    w.add_link(3, 5, 10.0, Some(1.0));
    w
}

fn coflow(id: u64, s: usize, d: usize, gb: f64) -> CoflowState {
    CoflowState::from_coflow(&Coflow::new(
        id,
        vec![Flow { id: 0, src_dc: s, dst_dc: d, volume: gb * GB }],
    ))
}

fn sharded(shards: usize) -> ShardedEngine {
    let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
    ShardedEngine::new(
        two_triangles(),
        Box::new(policy),
        EngineConfig { check_feasibility: true, shards, ..Default::default() },
    )
}

fn assert_same_rates(a: &ShardedEngine, b: &ShardedEngine, what: &str) {
    assert_eq!(
        a.rates_snapshot(),
        b.rates_snapshot(),
        "allocations diverged ({what}): {} shards vs {} shards",
        a.num_shards(),
        b.num_shards()
    );
}

#[test]
fn sharded_bit_identical_to_single_shard() {
    let mut engines = [sharded(1), sharded(2), sharded(4)];
    let arrivals = [(1, 0, 1, 5.0), (2, 3, 4, 7.0), (3, 1, 2, 3.0), (4, 4, 5, 9.0)];
    let mut now = 0.0;
    for &(id, s, d, gb) in &arrivals {
        for e in engines.iter_mut() {
            e.insert(coflow(id, s, d, gb));
            e.round(now, RoundTrigger::CoflowArrival);
            e.drain(0.05, 0.0);
        }
        let (base, rest) = engines.split_first().unwrap();
        for e in rest {
            assert_same_rates(base, e, &format!("after arrival {id}"));
        }
        now += 0.05;
    }

    // Bandwidth changes dirty both triangles: every shard re-solves its
    // component, the single-shard engine re-solves both sequentially.
    for e in engines.iter_mut() {
        assert_eq!(
            e.handle_wan_event_at(&LinkEvent::SetBandwidth(0, 1, 4.0), now),
            WanReaction::Reoptimize
        );
        assert_eq!(
            e.handle_wan_event_at(&LinkEvent::SetBandwidth(3, 4, 4.0), now),
            WanReaction::Reoptimize
        );
        e.round(now, RoundTrigger::WanChange);
    }
    {
        let (base, rest) = engines.split_first().unwrap();
        for e in rest {
            assert_same_rates(base, e, "after bandwidth change");
        }
    }

    // A structural failure: paths recompute, edge sets shift, and the
    // sharded front-ends redistribute ownership from scratch. Still
    // bit-identical afterwards.
    for e in engines.iter_mut() {
        assert_eq!(
            e.handle_wan_event_at(&LinkEvent::Fail(1, 2), now),
            WanReaction::Structural
        );
        e.round(now, RoundTrigger::WanChange);
    }
    {
        let (base, rest) = engines.split_first().unwrap();
        for e in rest {
            assert_same_rates(base, e, "after structural failure");
        }
    }

    // Run everything to completion, comparing at every completion round.
    for step in 0..64 {
        if engines.iter().all(|e| e.is_empty()) {
            break;
        }
        let dt = engines[0]
            .next_completion(now)
            .map(|t| (t - now).max(1e-6))
            .unwrap_or(0.05);
        let mut finished: Vec<Vec<CoflowId>> = Vec::new();
        for e in engines.iter_mut() {
            e.drain(dt, 0.0);
            let mut f = e.take_finished();
            f.sort_unstable();
            finished.push(f);
            if !e.is_empty() {
                e.round(now + dt, RoundTrigger::CoflowFinish);
            }
        }
        assert!(
            finished.iter().all(|f| *f == finished[0]),
            "completion sets diverged at step {step}: {finished:?}"
        );
        let (base, rest) = engines.split_first().unwrap();
        for e in rest {
            assert_same_rates(base, e, &format!("completion step {step}"));
        }
        now += dt;
    }
    assert!(engines.iter().all(|e| e.is_empty()), "runs did not complete");

    // Same work done, not just the same answers: LP solve counts, dirty
    // component counts, and Γ-cache hits all match exactly.
    let stats: Vec<_> = engines.iter_mut().map(|e| e.take_stats()).collect();
    for s in &stats[1..] {
        assert_eq!(s.lp_solves, stats[0].lp_solves, "solve counts must match");
        assert_eq!(s.component_solves, stats[0].component_solves);
        assert_eq!(s.gamma_cache_hits, stats[0].gamma_cache_hits);
    }
}

/// Recompute the active table's per-coflow candidate edge sets exactly the
/// way the engine defines them (unfinished groups, k-truncated paths) and
/// decompose from scratch.
fn fresh_partition(e: &RoundEngine) -> decompose::Components {
    let k = e.k_paths();
    let items: Vec<Vec<EdgeId>> = e
        .active()
        .iter()
        .map(|cf| {
            let mut es: Vec<EdgeId> = Vec::new();
            for (g, &rem) in cf.groups.iter().zip(&cf.remaining) {
                if rem <= 1e-9 {
                    continue;
                }
                for p in e.paths().get(g.src, g.dst).iter().take(k) {
                    es.extend_from_slice(&p.edges);
                }
            }
            es.sort_unstable();
            es.dedup();
            es
        })
        .collect();
    decompose::decompose(e.wan().num_edges(), &items)
}

fn assert_partition_fresh(e: &RoundEngine, what: &str) {
    assert!(!e.partition_is_stale(), "partition still stale after round ({what})");
    let fresh = fresh_partition(e);
    let live = e.partition();
    assert_eq!(live.comp_of, fresh.comp_of, "comp_of diverged ({what})");
    assert_eq!(live.members, fresh.members, "members diverged ({what})");
    assert_eq!(live.edges, fresh.edges, "edge unions diverged ({what})");
}

#[test]
fn prop_incremental_partition() {
    let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
    let mut e = RoundEngine::new(
        two_triangles(),
        Box::new(policy),
        EngineConfig { check_feasibility: true, ..Default::default() },
    );
    let mut now = 0.0;

    // Arrivals (membership events: every one invalidates the partition).
    for &(id, s, d, gb) in
        &[(1, 0, 1, 5.0), (2, 3, 4, 7.0), (3, 1, 2, 3.0), (4, 4, 5, 9.0), (5, 0, 2, 2.0)]
    {
        e.insert(coflow(id, s, d, gb));
        assert!(e.partition_is_stale(), "insert must invalidate the partition");
        e.round(now, RoundTrigger::CoflowArrival);
        assert_partition_fresh(&e, &format!("arrival {id}"));
    }

    // Steady-state rounds (drain + capacity fluctuation): the partition is
    // NOT stale — the reuse path must still equal a full rebuild.
    e.drain(0.05, 0.0);
    now += 0.05;
    assert_eq!(
        e.handle_wan_event_at(&LinkEvent::SetBandwidth(0, 1, 6.0), now),
        WanReaction::Reoptimize
    );
    assert!(!e.partition_is_stale(), "bandwidth change must not force a rebuild");
    e.round(now, RoundTrigger::WanChange);
    assert_partition_fresh(&e, "bandwidth change");

    // Structural change: paths recompute, edge sets change shape.
    assert_eq!(e.handle_wan_event_at(&LinkEvent::Fail(1, 2), now), WanReaction::Structural);
    assert!(e.partition_is_stale(), "structural event must invalidate the partition");
    e.round(now, RoundTrigger::WanChange);
    assert_partition_fresh(&e, "link failure");
    assert_eq!(e.handle_wan_event_at(&LinkEvent::Recover(1, 2), now), WanReaction::Structural);
    e.round(now, RoundTrigger::WanChange);
    assert_partition_fresh(&e, "link recovery");

    // Departures: run to empty, checking after every completion round.
    let mut guard = 0;
    while !e.is_empty() {
        guard += 1;
        assert!(guard < 64, "run did not converge");
        let dt = e.next_completion(now).map(|t| (t - now).max(1e-6)).unwrap_or(0.05);
        e.drain(dt, 0.0);
        now += dt;
        let finished = e.take_finished();
        if !e.is_empty() {
            e.round(now, RoundTrigger::CoflowFinish);
            assert_partition_fresh(&e, &format!("after completions {finished:?}"));
        }
    }
}
