//! End-to-end scheduler+simulator integration: Terra vs baselines on real
//! workloads, online arrivals, WAN events, deadline pipelines.

use terra::baselines;
use terra::net::{topologies, LinkEvent};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::sim::{Job, SimConfig, Simulation};
use terra::workloads::{assign_deadlines, WorkloadConfig, WorkloadGen, WorkloadKind};

fn run(wan: &terra::net::Wan, policy: Box<dyn terra::scheduler::Policy>, n: usize) -> terra::sim::Report {
    let cfg = WorkloadConfig::new(WorkloadKind::BigBench, 11);
    let jobs = WorkloadGen::with_config(cfg).jobs(wan, n);
    let mut sim = Simulation::new(wan.clone(), policy, SimConfig::default());
    sim.run_jobs(jobs)
}

#[test]
fn terra_beats_per_flow_on_swan() {
    let wan = topologies::swan();
    let t = run(&wan, Box::new(TerraPolicy::default()), 25);
    let f = run(&wan, baselines::by_name("per-flow").unwrap(), 25);
    assert_eq!(t.unfinished(), 0);
    assert_eq!(f.unfinished(), 0);
    assert!(
        t.avg_jct() < f.avg_jct(),
        "terra {} >= per-flow {}",
        t.avg_jct(),
        f.avg_jct()
    );
    // WAN utilization should improve too (Table 2 direction).
    assert!(t.utilization() >= f.utilization() * 0.95);
}

#[test]
fn all_policies_complete_all_jobs_on_gscale() {
    let wan = topologies::gscale();
    for name in baselines::all_policy_names() {
        let rep = run(&wan, baselines::by_name(name).unwrap(), 6);
        assert_eq!(rep.unfinished(), 0, "{name} starved coflows");
        assert!(rep.avg_jct() > 0.0);
    }
}

#[test]
fn online_arrivals_preserve_work() {
    // Jobs arriving over time: total transferred must equal total volume.
    let wan = topologies::swan();
    let cfg = WorkloadConfig::new(WorkloadKind::Fb, 5);
    let jobs = WorkloadGen::with_config(cfg).jobs(&wan, 30);
    let expected: f64 = jobs.iter().map(|j| j.total_volume()).sum();
    let mut sim = Simulation::new(wan, Box::new(TerraPolicy::default()), SimConfig::default());
    let rep = sim.run_jobs(jobs);
    assert!(
        (rep.transferred_gbit - expected).abs() < 1e-3 * expected.max(1.0),
        "transferred {} != submitted {}",
        rep.transferred_gbit,
        expected
    );
}

#[test]
fn wan_failure_mid_workload_recovers() {
    let wan = topologies::swan();
    let cfg = WorkloadConfig::new(WorkloadKind::TpcH, 9);
    let jobs = WorkloadGen::with_config(cfg).jobs(&wan, 10);
    let mut sim = Simulation::new(wan, Box::new(TerraPolicy::default()), SimConfig::default());
    for j in jobs {
        sim.add_job(j);
    }
    sim.add_wan_event(60.0, LinkEvent::Fail(0, 1));
    sim.add_wan_event(300.0, LinkEvent::Recover(0, 1));
    let rep = sim.run();
    assert_eq!(rep.unfinished(), 0, "failure should not strand coflows");
}

#[test]
fn deadline_pipeline_admitted_mostly_met() {
    let wan = topologies::swan();
    let cfg = WorkloadConfig::new(WorkloadKind::BigBench, 13);
    let mut jobs = WorkloadGen::with_config(cfg).jobs(&wan, 15);
    assign_deadlines(&mut jobs, &wan, 4.0);
    let mut sim = Simulation::new(wan, Box::new(TerraPolicy::default()), SimConfig::default());
    let rep = sim.run_jobs(jobs);
    // In simulation (instant control loop), every admitted coflow meets its
    // deadline (§6.4 "all admitted coflows completed in Terra").
    let admitted: Vec<_> = rep
        .coflows
        .iter()
        .filter(|c| c.deadline.is_some() && c.admitted && c.finish.is_some())
        .collect();
    assert!(!admitted.is_empty());
    let met = admitted.iter().filter(|c| c.met_deadline()).count();
    // The GK ε-approximation and cross-round rerouting interference let a
    // few borderline admissions slip past their deadline (the paper's
    // testbed sees the same effect, §6.4); the bulk must hold.
    assert!(
        met as f64 >= 0.85 * admitted.len() as f64,
        "only {met}/{} admitted met deadlines",
        admitted.len()
    );
}

#[test]
fn sub_second_coflows_hurt_by_coordination_delay() {
    // Fig 7d: centralized scheduling penalizes tiny coflows when the
    // control loop is not instant.
    let wan = topologies::swan();
    let job = Job::map_reduce(
        1,
        0.0,
        0.0,
        vec![terra::coflow::Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 0.5 }],
    );
    let mut fast = Simulation::new(
        wan.clone(),
        Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() })),
        SimConfig::default(),
    );
    let fast_jct = fast.run_jobs(vec![job.clone()]).jobs[0].jct().unwrap();
    let mut slow = Simulation::new(
        wan,
        Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() })),
        SimConfig { coordination_delay_s: 0.5, ..Default::default() },
    );
    let slow_jct = slow.run_jobs(vec![job]).jobs[0].jct().unwrap();
    assert!(slow_jct > fast_jct + 0.4, "delay not reflected: {slow_jct} vs {fast_jct}");
}
