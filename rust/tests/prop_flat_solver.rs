//! Property tests for the flat-CSR solver core and the parallel component
//! solves (via the in-tree `util::prop` harness):
//!
//! 1. the flat GK core is **bit-identical** to the jagged reference — λ and
//!    every rate, f64 bit for bit — on random instances drawn from all
//!    three evaluation topologies, cold and warm-started;
//! 2. the flat workspace-backed max-min filling is bit-identical to the
//!    jagged progressive filling;
//! 3. a `TerraPolicy` on `SolverRepr::Jagged` and one on `SolverRepr::Flat`
//!    produce bit-identical allocations through whole engine rounds
//!    (Γ-cache, warm starts, CSR block reuse, work conservation included);
//! 4. engine rounds with `workers = N` produce bit-identical allocations to
//!    `workers = 1` for a multi-component workload.

use terra::coflow::{Coflow, Flow};
use terra::engine::{EngineConfig, RoundEngine};
use terra::lp::flat::{FlatMcf, GkScratch};
use terra::lp::{gk, maxmin, GroupDemand, McfInstance, SolverRepr};
use terra::net::paths::PathSet;
use terra::net::topologies;
use terra::net::{LinkEvent, Wan};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::{CoflowState, RoundTrigger};
use terra::util::prop::{forall, PropConfig};
use terra::util::rng::Pcg32;

/// Compare two optional solutions f64-bit for f64-bit.
fn assert_bit_identical(
    a: &Option<terra::lp::McfSolution>,
    b: &Option<terra::lp::McfSolution>,
    what: &str,
) -> Result<(), String> {
    match (a, b) {
        (None, None) => Ok(()),
        (Some(a), Some(b)) => {
            if a.lambda.to_bits() != b.lambda.to_bits() {
                return Err(format!("{what}: λ {} vs {}", a.lambda, b.lambda));
            }
            if a.rates.len() != b.rates.len() {
                return Err(format!("{what}: group count differs"));
            }
            for (k, (ra, rb)) in a.rates.iter().zip(&b.rates).enumerate() {
                if ra.len() != rb.len() {
                    return Err(format!("{what}: group {k} path count differs"));
                }
                for (p, (x, y)) in ra.iter().zip(rb).enumerate() {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("{what}: rate[{k}][{p}] {x} vs {y}"));
                    }
                }
            }
            Ok(())
        }
        (a, b) => Err(format!("{what}: one side None ({} vs {})", a.is_some(), b.is_some())),
    }
}

/// Random MCF instance over a topology's k-shortest-path sets, with
/// per-edge capacity jitter and occasional degenerate (gray-failure)
/// residuals and zero-volume groups.
fn gen_instance(wan: &Wan, paths: &PathSet, k: usize, rng: &mut Pcg32, size: usize) -> McfInstance {
    let n = wan.num_nodes();
    let mut cap: Vec<f64> = wan.capacities();
    for c in &mut cap {
        let roll = rng.below(10);
        *c *= rng.uniform(0.3, 1.5);
        if roll == 0 {
            *c = 1e-10; // gray failure: must behave exactly like down
        } else if roll == 1 {
            *c = 0.0;
        }
    }
    let ng = 1 + rng.below(size.clamp(1, 6));
    let groups = (0..ng)
        .map(|_| {
            let s = rng.below(n);
            let mut d = rng.below(n);
            while d == s {
                d = rng.below(n);
            }
            let volume = if rng.below(7) == 0 { 0.0 } else { rng.uniform(1.0, 300.0) };
            GroupDemand {
                volume,
                paths: paths.get(s, d).iter().take(k).map(|p| p.edges.clone()).collect(),
            }
        })
        .collect();
    McfInstance { cap, groups }
}

fn check_gk_equivalence(inst: &McfInstance) -> Result<(), String> {
    let eps = gk::DEFAULT_EPSILON;
    let flat = gk::solve_warm(inst, eps, None);
    let jagged = gk::solve_warm_jagged(inst, eps, None);
    assert_bit_identical(&flat, &jagged, "cold")?;
    // Warm-started from the cold solution (when one exists), and from a
    // deliberately ragged warm matrix (short / missing groups).
    if let Some(sol) = &jagged {
        let wf = gk::solve_warm(inst, eps, Some(&sol.rates));
        let wj = gk::solve_warm_jagged(inst, eps, Some(&sol.rates));
        assert_bit_identical(&wf, &wj, "warm")?;
        let ragged: Vec<Vec<f64>> =
            sol.rates.iter().take(1).map(|r| r.iter().take(1).copied().collect()).collect();
        let rf = gk::solve_warm(inst, eps, Some(&ragged));
        let rj = gk::solve_warm_jagged(inst, eps, Some(&ragged));
        assert_bit_identical(&rf, &rj, "ragged warm")?;
    }
    Ok(())
}

#[test]
fn prop_flat_gk_bit_identical_to_jagged_on_swan() {
    let wan = topologies::swan();
    let paths = PathSet::compute(&wan, 4);
    forall(
        PropConfig { cases: 40, seed: 0xF1A7, max_size: 6 },
        |rng, size| gen_instance(&wan, &paths, 4, rng, size),
        check_gk_equivalence,
    );
}

#[test]
fn prop_flat_gk_bit_identical_to_jagged_on_gscale() {
    let wan = topologies::gscale();
    let paths = PathSet::compute(&wan, 3);
    forall(
        PropConfig { cases: 15, seed: 0x65CA1E, max_size: 5 },
        |rng, size| gen_instance(&wan, &paths, 3, rng, size),
        check_gk_equivalence,
    );
}

#[test]
fn prop_flat_gk_bit_identical_to_jagged_on_att() {
    let wan = topologies::att();
    let paths = PathSet::compute(&wan, 3);
    forall(
        PropConfig { cases: 10, seed: 0xA77, max_size: 4 },
        |rng, size| gen_instance(&wan, &paths, 3, rng, size),
        check_gk_equivalence,
    );
}

#[test]
fn prop_flat_maxmin_bit_identical_to_jagged() {
    let wan = topologies::swan();
    let paths = PathSet::compute(&wan, 3);
    forall(
        PropConfig { cases: 25, seed: 0x3A3, max_size: 6 },
        |rng, size| {
            let inst = gen_instance(&wan, &paths, 3, rng, size);
            // Occasionally pin every group to one path to hit the
            // water-fill fast path.
            let single = rng.below(3) == 0;
            let groups: Vec<GroupDemand> = inst
                .groups
                .into_iter()
                .map(|mut g| {
                    if single {
                        g.paths.truncate(1);
                    }
                    g
                })
                .collect();
            let weights: Vec<f64> = groups.iter().map(|g| g.volume.max(0.25)).collect();
            (McfInstance { cap: inst.cap, groups }, weights)
        },
        |(inst, weights)| {
            let jagged = maxmin::max_min_rates(&inst.cap, &inst.groups, weights);
            let mut flat = FlatMcf::from_instance(inst);
            let mut ws = GkScratch::default();
            let flat_rates = maxmin::max_min_rates_ws(&mut flat, weights, &mut ws);
            if flat_rates.len() != jagged.len() {
                return Err("group count differs".into());
            }
            for (k, (a, b)) in flat_rates.iter().zip(&jagged).enumerate() {
                if a.len() != b.len() {
                    return Err(format!("group {k} path count differs"));
                }
                for (p, (x, y)) in a.iter().zip(b).enumerate() {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("rate[{k}][{p}]: {x} vs {y}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Random multi-group coflows on SWAN.
fn gen_coflows(rng: &mut Pcg32, n_nodes: usize, count: usize, first_id: u64) -> Vec<CoflowState> {
    (0..count)
        .map(|i| {
            let flows = (0..1 + rng.below(3))
                .map(|f| {
                    let s = rng.below(n_nodes);
                    let mut d = rng.below(n_nodes);
                    while d == s {
                        d = rng.below(n_nodes);
                    }
                    Flow {
                        id: f as u64,
                        src_dc: s,
                        dst_dc: d,
                        volume: rng.uniform(5.0, 400.0),
                    }
                })
                .collect();
            let mut st = CoflowState::from_coflow(&Coflow::new(first_id + i as u64, flows));
            st.admitted = true;
            st
        })
        .collect()
}

/// Drive two engines through the same arrival/drain/WAN-event schedule and
/// compare allocations bit-for-bit after every round.
fn lockstep_engines(
    mut a: RoundEngine,
    mut b: RoundEngine,
    seed: u64,
    what: &str,
) -> Result<(), String> {
    let mut rng = Pcg32::new(seed);
    let n = a.wan().num_nodes();
    let mut next_id = 1u64;
    let mut now = 0.0;
    for step in 0..8 {
        let count = 1 + rng.below(3);
        let batch = gen_coflows(&mut rng, n, count, next_id);
        next_id += batch.len() as u64;
        for st in &batch {
            a.insert(st.clone());
            b.insert(st.clone());
        }
        a.round(now, RoundTrigger::CoflowArrival);
        b.round(now, RoundTrigger::CoflowArrival);
        if a.alloc().rates != b.alloc().rates {
            return Err(format!("{what}: allocations diverged at step {step}"));
        }
        // Occasional WAN events: a sub-ρ dip, then sometimes a qualifying
        // drop on a random link.
        if rng.below(2) == 0 {
            let links: Vec<(usize, usize, f64)> = {
                let w = a.wan();
                w.links().iter().map(|l| (l.src, l.dst, l.base_capacity)).collect()
            };
            let (u, v, base) = links[rng.below(links.len())];
            let frac = if rng.below(2) == 0 { 0.9 } else { 0.4 };
            let ev = LinkEvent::SetBandwidth(u, v, base * frac);
            let ra = a.handle_wan_event(&ev);
            let rb = b.handle_wan_event(&ev);
            if ra != rb {
                return Err(format!("{what}: reactions diverged at step {step}"));
            }
            if let Some(trigger) = ra.trigger() {
                a.round(now, trigger);
                b.round(now, trigger);
                if a.alloc().rates != b.alloc().rates {
                    return Err(format!("{what}: post-event divergence at step {step}"));
                }
            }
        }
        a.drain(0.05, 0.0);
        b.drain(0.05, 0.0);
        a.take_finished();
        b.take_finished();
        now += 0.05;
    }
    let (sa, sb) = (a.take_stats(), b.take_stats());
    if sa.lp_solves != sb.lp_solves || sa.component_solves != sb.component_solves {
        return Err(format!(
            "{what}: stats diverged (lp {} vs {}, comps {} vs {})",
            sa.lp_solves, sb.lp_solves, sa.component_solves, sb.component_solves
        ));
    }
    Ok(())
}

fn swan_engine(repr: SolverRepr, workers: usize, k: usize) -> RoundEngine {
    let policy = TerraPolicy::new(TerraConfig { k, repr, ..Default::default() });
    RoundEngine::new(
        topologies::swan(),
        Box::new(policy),
        EngineConfig { check_feasibility: true, workers, ..Default::default() },
    )
}

/// Whole-pipeline repr equivalence: Γ-cache ordering solves, warm-started
/// allocation solves, CSR block reuse across rounds and epochs, and the
/// work-conservation filling must all agree bit-for-bit between the jagged
/// and flat representations.
#[test]
fn prop_repr_flat_equals_jagged_through_engine_rounds() {
    for seed in [1u64, 7, 42] {
        lockstep_engines(
            swan_engine(SolverRepr::Jagged, 1, 5),
            swan_engine(SolverRepr::Flat, 1, 5),
            seed,
            &format!("repr seed {seed}"),
        )
        .unwrap();
    }
}

/// Parallel component solves must be bit-identical to sequential for any
/// worker count. k = 1 pod-local coflows keep the active set factored into
/// many components, so dirty sets regularly span several components and the
/// parallel path actually executes.
#[test]
fn prop_workers_parallel_equals_sequential() {
    let pod_engine = |workers: usize| {
        let policy = TerraPolicy::new(TerraConfig { k: 1, ..Default::default() });
        RoundEngine::new(
            topologies::swan(),
            Box::new(policy),
            EngineConfig { check_feasibility: true, workers, ..Default::default() },
        )
    };
    for (seed, workers) in [(3u64, 2usize), (9, 3), (11, 8)] {
        let mut seq = pod_engine(1);
        let mut par = pod_engine(workers);
        // Pod-local arrivals on adjacent pairs: many independent components.
        let pairs: Vec<(usize, usize)> = {
            let w = seq.wan();
            w.links().iter().map(|l| (l.src, l.dst)).collect()
        };
        let mut rng = Pcg32::new(seed);
        let mut now = 0.0;
        let mut next_id = 1u64;
        for step in 0..6 {
            for _ in 0..2 + rng.below(3) {
                let (s, d) = pairs[rng.below(pairs.len())];
                let mut st = CoflowState::from_coflow(&Coflow::new(
                    next_id,
                    vec![Flow { id: 0, src_dc: s, dst_dc: d, volume: rng.uniform(20.0, 800.0) }],
                ));
                st.admitted = true;
                next_id += 1;
                seq.insert(st.clone());
                par.insert(st);
            }
            seq.round(now, RoundTrigger::CoflowArrival);
            par.round(now, RoundTrigger::CoflowArrival);
            assert_eq!(
                seq.alloc().rates,
                par.alloc().rates,
                "workers={workers} seed={seed} diverged at step {step}"
            );
            seq.drain(0.08, 0.0);
            par.drain(0.08, 0.0);
            seq.take_finished();
            par.take_finished();
            now += 0.08;
        }
        let (s1, s2) = (seq.take_stats(), par.take_stats());
        assert_eq!(s1.lp_solves, s2.lp_solves);
        assert_eq!(s1.component_solves, s2.component_solves);
        assert_eq!(s1.component_reuses, s2.component_reuses);
        assert_eq!(s1.gamma_cache_hits, s2.gamma_cache_hits);
    }
}
