//! Delta-enforcement protocol tests (controller ↔ agent control plane):
//! a round pushes only the FlowGroup rate vectors that changed (plus an
//! explicit revoke list) under a per-agent sequence number, with a
//! full-table sync on (re)connect and on `sync_request`. Fake agents —
//! raw TCP speaking the wire protocol — let the tests observe exactly what
//! the controller ships. Also: a fuzz-ish run of truncated/garbage/wrongly
//! typed control frames against a live controller, which must drop them
//! (or the connection) and keep scheduling.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use terra::api::TerraClient;
use terra::net::topologies;
use terra::overlay::protocol::{self, FlowSpec};
use terra::overlay::{Controller, ControllerHandle, TestbedConfig, BYTES_PER_GBPS};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::Policy;
use terra::util::json::Json;

fn policy(k: usize) -> Box<dyn Policy> {
    Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, k, ..Default::default() }))
}

fn gbit(x: f64) -> u64 {
    (x * BYTES_PER_GBPS) as u64
}

/// A fake agent: registers over the control channel but never moves data.
struct FakeAgent {
    ctrl: TcpStream,
}

impl FakeAgent {
    fn connect(handle: &ControllerHandle, dc: usize) -> FakeAgent {
        let mut ctrl = TcpStream::connect(handle.addr).unwrap();
        ctrl.set_nodelay(true).ok();
        let hello = Json::from_pairs([
            ("op", Json::from("hello")),
            ("dc", dc.into()),
            // Nothing ever connects here; peers-msg consumers ignore it.
            ("data_addr", "127.0.0.1:1".into()),
        ]);
        protocol::write_msg(&mut ctrl, &hello).unwrap();
        FakeAgent { ctrl }
    }

    /// Read one full control message with a deadline; `None` on timeout or
    /// EOF. Uses the resumable reader so a mid-frame read timeout cannot
    /// desync the stream.
    fn read_msg(&mut self, timeout: Duration) -> Option<Json> {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let timer = std::thread::spawn(move || {
            std::thread::sleep(timeout);
            stop2.store(true, Ordering::Relaxed);
        });
        self.ctrl.set_read_timeout(Some(Duration::from_millis(10))).ok();
        let got = protocol::read_msg_resumable(&mut self.ctrl, &stop).ok().flatten();
        stop.store(true, Ordering::Relaxed);
        drop(timer); // detach; it only flips an already-set flag
        got
    }

    /// Skip messages until one with `op` arrives.
    fn read_op(&mut self, op: &str, timeout: Duration) -> Option<Json> {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            let msg = self.read_msg(deadline.saturating_duration_since(Instant::now()))?;
            if msg.get("op").and_then(|o| o.as_str()) == Some(op) {
                return Some(msg);
            }
        }
        None
    }

    fn send(&mut self, msg: &Json) {
        protocol::write_msg(&mut self.ctrl, msg).unwrap();
    }
}

fn delta_keys(msg: &Json, field: &str) -> Vec<(u64, u64)> {
    let mut keys: Vec<(u64, u64)> = msg
        .get(field)
        .and_then(|u| u.as_arr())
        .map(|arr| {
            arr.iter()
                .filter_map(|e| {
                    Some((e.get("coflow")?.as_u64()?, e.get("dst")?.as_u64()?))
                })
                .collect()
        })
        .unwrap_or_default();
    keys.sort_unstable();
    keys
}

/// End-to-end delta semantics on edge-disjoint components (fig1a, k = 1:
/// each pair pins to its direct edge, so coflows on different pairs are
/// independent): a round that re-solved one component pushes rates only to
/// that component's senders; everyone else hears nothing.
#[test]
fn delta_pushes_only_changed_components() {
    let handle =
        Controller::spawn(TestbedConfig::new(topologies::fig1a(), 1), policy(1)).unwrap();
    let mut agents: Vec<FakeAgent> =
        (0..3).map(|dc| FakeAgent::connect(&handle, dc)).collect();
    assert!(handle.wait_ready(3, Duration::from_secs(5)));
    let long = Duration::from_secs(5);

    // Registration: every agent gets a (here empty) full sync baseline.
    for a in agents.iter_mut() {
        let full = a.read_op("rates_full", long).expect("full sync on connect");
        assert_eq!(full.get("seq").and_then(|s| s.as_u64()), Some(1));
        assert!(delta_keys(&full, "entries").is_empty());
    }

    // Coflow 1: A(0) → B(1), pinned to edge A→B. Only agent 0 hears.
    let mut client = TerraClient::connect(handle.addr).unwrap();
    let c1 = client
        .submit_coflow(&[FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(4000.0) }], None)
        .unwrap() as u64;
    let d = agents[0].read_op("rates_delta", long).expect("delta for coflow 1");
    assert_eq!(d.get("seq").and_then(|s| s.as_u64()), Some(2));
    assert_eq!(delta_keys(&d, "updates"), vec![(c1, 1)]);
    assert!(delta_keys(&d, "revoke").is_empty());

    // Coflow 2: C(2) → B(1), edge C→B — a different component. Agent 2
    // hears about it; agent 0's table is untouched, so it must hear
    // NOTHING (control traffic is O(changed flows)).
    let c2 = client
        .submit_coflow(&[FlowSpec { id: 0, src_dc: 2, dst_dc: 1, bytes: gbit(4000.0) }], None)
        .unwrap() as u64;
    let d = agents[2].read_op("rates_delta", long).expect("delta for coflow 2");
    assert_eq!(delta_keys(&d, "updates"), vec![(c2, 1)]);
    // Agent 0's table is untouched, so no *rate* frame may arrive — only
    // liveness heartbeats, which the controller ships even on quiet wires
    // (they feed the agents' degraded-mode watchdog).
    let quiet = Instant::now() + Duration::from_millis(600);
    while Instant::now() < quiet {
        let Some(msg) = agents[0].read_msg(quiet.saturating_duration_since(Instant::now()))
        else {
            break;
        };
        assert_eq!(
            msg.get("op").and_then(|o| o.as_str()),
            Some("hb"),
            "agent 0 must not be pushed an unchanged table: got {msg}"
        );
    }

    // Coflow 3 shares coflow 1's component (same pair, much smaller):
    // SRTF flips the pair's rates, so agent 0 gets ONE delta carrying both
    // entries, sequence-contiguous with its last.
    let c3 = client
        .submit_coflow(&[FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(1.0) }], None)
        .unwrap() as u64;
    let d = agents[0].read_op("rates_delta", long).expect("delta for coflow 3's component");
    assert_eq!(d.get("seq").and_then(|s| s.as_u64()), Some(3), "per-agent seq is contiguous");
    let keys = delta_keys(&d, "updates");
    assert!(keys.contains(&(c3, 1)), "new coflow's entry missing: {keys:?}");

    // Explicit resync: the full table comes back with both of agent 0's
    // entries and a fresh baseline seq.
    agents[0].send(&Json::from_pairs([("op", Json::from("sync_request"))]));
    let full = agents[0].read_op("rates_full", long).expect("requested full sync");
    let keys = delta_keys(&full, "entries");
    assert_eq!(keys, vec![(c1, 1), (c3, 1)]);
    assert_eq!(full.get("seq").and_then(|s| s.as_u64()), Some(4));

    // Reconnect fallback: a replacement agent for dc 0 starts from a
    // fresh connection and receives the current table as a full sync.
    drop(agents.remove(0));
    let mut replacement = FakeAgent::connect(&handle, 0);
    let full = replacement.read_op("rates_full", long).expect("full sync on reconnect");
    assert_eq!(full.get("seq").and_then(|s| s.as_u64()), Some(1), "fresh connection, fresh seq");
    assert_eq!(delta_keys(&full, "entries"), vec![(c1, 1), (c3, 1)]);

    let stats = handle.delta_stats();
    assert!(stats.full_syncs >= 5, "3 connects + 1 request + 1 reconnect: {stats:?}");
    assert!(stats.delta_msgs >= 2, "{stats:?}");
    assert!(stats.delta_entries >= 3, "{stats:?}");
    handle.shutdown();
}

/// Fuzz-ish hardening run: truncated frames, garbage bytes, oversized
/// length prefixes, non-JSON bodies, and well-formed JSON with missing or
/// wrongly-typed fields must never panic the controller — each is dropped
/// (or its connection closed), and scheduling keeps working afterwards.
#[test]
fn malformed_control_frames_are_survivable() {
    let handle =
        Controller::spawn(TestbedConfig::new(topologies::fig1a(), 3), policy(3)).unwrap();

    // Raw byte-level garbage, each on its own connection.
    let raw_payloads: Vec<Vec<u8>> = vec![
        vec![0xDE, 0xAD, 0xBE, 0xEF, 0xDE, 0xAD, 0xBE, 0xEF],
        u32::MAX.to_le_bytes().to_vec(),             // absurd length prefix
        {
            let mut v = 5u32.to_le_bytes().to_vec(); // valid length, junk body
            v.extend_from_slice(b"nope!");
            v
        },
        3u32.to_le_bytes().to_vec(),                 // truncated body, then hangup
    ];
    for payload in raw_payloads {
        let mut s = TcpStream::connect(handle.addr).unwrap();
        s.write_all(&payload).unwrap();
        // Dropped here: the controller sees EOF mid- or post-frame.
    }

    // Structurally valid JSON with hostile contents.
    let json_payloads = [
        r#"42"#,
        r#"{"op":"submit","flows":42}"#,
        r#"{"op":"submit","flows":[{"id":"x"}]}"#,
        r#"{"op":"submit","flows":[{"id":7,"src":99,"dst":1,"bytes":10}]}"#,
        r#"{"op":"update","cid":123456,"flows":[]}"#,
        r#"{"op":"update","cid":{},"flows":[[]]}"#,
        r#"{"op":"status"}"#,
        r#"{"op":"wan_event","kind":"bw","u":7,"v":9}"#,
        r#"{"op":"wan_event","kind":[],"u":0,"v":1}"#,
        r#"{"op":"hello","dc":9999,"data_addr":"garbage"}"#,
        r#"{"op":"hello","data_addr":"no dc"}"#,
        r#"{"op":"group_done","coflow":1}"#,
        r#"{"op":"no_such_op"}"#,
    ];
    for text in json_payloads {
        let mut s = TcpStream::connect(handle.addr).unwrap();
        let msg = Json::parse(text).unwrap();
        protocol::write_msg(&mut s, &msg).unwrap();
        // Some of these get an error reply, some a drop; we only require
        // that reading doesn't hang forever and nothing crashes.
        s.set_read_timeout(Some(Duration::from_millis(200))).ok();
        let _ = protocol::read_msg(&mut s);
    }

    // An out-of-range flow endpoint must be *rejected*, not panic a later
    // scheduling round.
    {
        let mut s = TcpStream::connect(handle.addr).unwrap();
        let msg = Json::parse(
            r#"{"op":"submit","flows":[{"id":0,"src":0,"dst":77,"bytes":1000}]}"#,
        )
        .unwrap();
        protocol::write_msg(&mut s, &msg).unwrap();
        let reply = protocol::read_msg(&mut s).unwrap().expect("reply");
        assert!(reply.get("error").is_some(), "expected rejection, got {reply}");
    }

    // The controller is still alive and scheduling: a valid submission
    // goes through and gets an allocation (no agents needed for that).
    let mut client = TerraClient::connect(handle.addr).unwrap();
    let cid = client
        .submit_coflow(&[FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(100.0) }], None)
        .unwrap();
    assert!(cid > 0);
    assert!(handle.scheduled_rate(cid as u64) > 0.0, "engine stopped allocating");
    assert!(handle.rounds() >= 1);
    handle.shutdown();
}

/// Regression (reconnect/resync ordering race): when a replacement
/// connection for a dc arrives while the old one is still up, the
/// controller must atomically retire the old sender queue *before* the new
/// baseline goes out. The observable contract on the new socket: the very
/// first frame is a `rates_full` baseline (seq 1) — no delta queued for the
/// predecessor may leak ahead of it — and every subsequent rate frame is
/// sequence-contiguous with that baseline.
#[test]
fn reconnect_baseline_precedes_any_delta_on_new_socket() {
    let handle =
        Controller::spawn(TestbedConfig::new(topologies::fig1a(), 1), policy(1)).unwrap();
    let mut old = FakeAgent::connect(&handle, 0);
    let long = Duration::from_secs(5);
    assert!(old.read_op("rates_full", long).is_some(), "baseline sync");

    // Build a live table and keep deltas streaming at the old connection
    // (descending volumes so SRTF reshuffles rates on every arrival).
    let mut client = TerraClient::connect(handle.addr).unwrap();
    for i in 0..6u64 {
        let bytes = gbit(4000.0 / (i + 1) as f64);
        client
            .submit_coflow(&[FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes }], None)
            .unwrap();
    }

    // The race window: reconnect while the old connection is still open and
    // its queue possibly non-empty.
    let mut new = FakeAgent::connect(&handle, 0);
    let first = new.read_msg(long).expect("first frame on the new socket");
    assert_eq!(
        first.get("op").and_then(|o| o.as_str()),
        Some("rates_full"),
        "first frame on a replacement connection must be the full baseline, got {first}"
    );
    let mut last_seq =
        first.get("seq").and_then(|s| s.as_u64()).expect("baseline carries a seq");
    assert_eq!(last_seq, 1, "fresh connection starts a fresh sequence");

    // Everything after the baseline is a gapless per-connection stream.
    for i in 0..3u64 {
        client
            .submit_coflow(
                &[FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(0.5 + i as f64) }],
                None,
            )
            .unwrap();
        let d = new.read_op("rates_delta", long).expect("post-baseline delta");
        let seq = d.get("seq").and_then(|s| s.as_u64()).unwrap();
        assert_eq!(seq, last_seq + 1, "gap in the replacement connection's seq stream");
        last_seq = seq;
    }

    // The superseded connection was retired, not left to race: it winds
    // down to EOF instead of receiving frames addressed to its successor.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        assert!(Instant::now() < deadline, "old connection never closed");
        match old.read_msg(Duration::from_millis(500)) {
            Some(msg) => {
                // Frames already queued before retirement may still drain,
                // but nothing sequenced after the successor's baseline.
                if let Some(seq) = msg.get("seq").and_then(|s| s.as_u64()) {
                    assert!(seq <= 7, "stale connection received a successor frame: {msg}");
                }
            }
            None => break, // timeout or EOF; either way the wire is quiet
        }
    }
    handle.shutdown();
}

/// Regression: a failed rate push must not be silently swallowed. When an
/// agent's control socket dies without the controller noticing (no clean
/// reconnect yet), the async writer hits a write error; the controller must
/// count it, close that agent's queue, and serve a complete full-table sync
/// to the replacement connection.
#[test]
fn write_error_is_counted_and_recovered_by_full_sync() {
    let handle =
        Controller::spawn(TestbedConfig::new(topologies::fig1a(), 1), policy(1)).unwrap();
    let mut agent = FakeAgent::connect(&handle, 0);
    assert!(handle.wait_ready(1, Duration::from_secs(5)));
    let long = Duration::from_secs(5);
    assert!(agent.read_op("rates_full", long).is_some(), "baseline sync");

    // Kill the agent's socket out from under the controller. The stale
    // AgentConn stays registered, so rate pushes keep targeting the dead
    // stream until the writer thread reports the failure.
    drop(agent);

    let mut client = TerraClient::connect(handle.addr).unwrap();
    let mut last = 0u64;
    let deadline = Instant::now() + Duration::from_secs(10);
    while handle.delta_stats().write_errors == 0 {
        assert!(
            Instant::now() < deadline,
            "write error never surfaced: {:?}",
            handle.delta_stats()
        );
        // Each submission re-solves and pushes rates at the dead agent;
        // TCP buffering can absorb the first few frames before the RST.
        last = client
            .submit_coflow(
                &[FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(4000.0) }],
                None,
            )
            .unwrap() as u64;
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(handle.delta_stats().write_errors >= 1);

    // A replacement agent converges from a clean full sync: fresh seq,
    // complete table (all live coflows' groups, nothing lost with the
    // frames that died in the closed queue).
    let mut replacement = FakeAgent::connect(&handle, 0);
    let full = replacement.read_op("rates_full", long).expect("full sync on reconnect");
    assert_eq!(full.get("seq").and_then(|s| s.as_u64()), Some(1), "fresh connection, fresh seq");
    let keys = delta_keys(&full, "entries");
    assert!(
        keys.contains(&(last, 1)),
        "replacement sync missing live coflow {last}: {keys:?}"
    );
    handle.shutdown();
}

/// Liveness eviction drill: an agent whose control channel goes silent past
/// the liveness deadline is declared down — connection evicted, its coflows
/// parked with achieved progress preserved — and a reconnecting replacement
/// gets the full re-arm sequence: baseline sync, reset-flagged transfer
/// state sized from the preserved remaining, and fresh rates once the
/// coflow un-parks.
#[test]
fn silent_agent_is_evicted_parked_and_rearmed_on_reconnect() {
    let deadline = Duration::from_millis(2500);
    let cfg = TestbedConfig::new(topologies::fig1a(), 1).with_liveness_deadline(deadline);
    let handle = Controller::spawn(cfg, policy(1)).unwrap();
    let mut agent = FakeAgent::connect(&handle, 0);
    assert!(handle.wait_ready(1, Duration::from_secs(5)));
    let long = Duration::from_secs(5);
    assert!(agent.read_op("rates_full", long).is_some(), "baseline sync");

    let mut client = TerraClient::connect(handle.addr).unwrap();
    let c1 = client
        .submit_coflow(&[FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(4000.0) }], None)
        .unwrap() as u64;
    assert!(agent.read_op("rates_delta", long).is_some(), "rates for the coflow");

    // Refresh the agent's liveness clock with one last audible message,
    // then go silent with the socket still OPEN: eviction must key off
    // silence (a hung agent looks exactly like this), not off EOF.
    agent.send(&Json::from_pairs([("op", Json::from("sync_request"))]));
    assert!(agent.read_op("rates_full", long).is_some(), "requested full sync");
    let t_silent = Instant::now();

    let det = Instant::now() + Duration::from_secs(10);
    while !handle.agent_down(0) {
        assert!(Instant::now() < det, "silent agent never declared down");
        std::thread::sleep(Duration::from_millis(25));
    }
    let elapsed = t_silent.elapsed();
    assert!(
        elapsed >= deadline.mul_f64(0.6) && elapsed <= deadline + Duration::from_secs(3),
        "detection latency {elapsed:?} not anchored to the {deadline:?} deadline"
    );
    let stats = handle.liveness_stats();
    assert_eq!(stats.down_events, 1, "{stats:?}");
    assert_eq!(stats.up_events, 0, "{stats:?}");
    assert_eq!(handle.parked_coflows(), 1, "victim coflow must be parked, not dropped");
    let rem = handle.coflow_remaining_gbit(c1).expect("parked coflow lost from the engine");
    assert!(rem > 3500.0, "parked remaining {rem} Gbit lost achieved progress");

    // Replacement for the evicted dc: baseline full sync first, then the
    // reset re-arm for the parked coflow's sender side (budget sized from
    // the preserved remaining — never from zero, never from the original
    // volume), then a round re-rates the un-parked coflow.
    let mut replacement = FakeAgent::connect(&handle, 0);
    let full = replacement.read_op("rates_full", long).expect("full sync on reconnect");
    assert_eq!(full.get("seq").and_then(|s| s.as_u64()), Some(1), "fresh connection, fresh seq");
    let xfer = replacement.read_op("transfer", long).expect("reset transfer re-arm");
    assert_eq!(
        xfer.get("reset").and_then(|r| r.as_bool()),
        Some(true),
        "re-arm must be a reset: {xfer}"
    );
    assert_eq!(xfer.get("coflow").and_then(|x| x.as_u64()), Some(c1));
    assert_eq!(xfer.get("dst").and_then(|x| x.as_u64()), Some(1));
    let budget =
        xfer.get("bytes").and_then(|b| b.as_u64()).unwrap_or(0) as f64 / BYTES_PER_GBPS;
    assert!(budget > 3500.0, "re-arm budget {budget} Gbit dropped achieved progress");
    let rated = Instant::now() + Duration::from_secs(5);
    while handle.scheduled_rate(c1) <= 0.0 {
        assert!(Instant::now() < rated, "un-parked coflow never re-rated");
        std::thread::sleep(Duration::from_millis(20));
    }

    let stats = handle.liveness_stats();
    assert_eq!(stats.up_events, 1, "{stats:?}");
    assert_eq!(stats.down_events, 1, "no spurious re-eviction: {stats:?}");
    assert!(!handle.agent_down(0));
    assert_eq!(handle.parked_coflows(), 0, "reconnect must un-park everything");
    drop(agent); // the evicted socket outlived the whole drill; close it last
    handle.shutdown();
}

/// Regression: a replayed `group_done` for a coflow the controller already
/// saw finish must be absorbed — no double-complete (the recorded CCT is
/// immutable), no spurious scheduling round, no resurrecting the entry
/// `take_finished` already removed — and the controller stays fully
/// serviceable afterwards. Agents replay buffered completions after
/// reconnects, so this is a wire-visible contract, not an internal detail.
#[test]
fn replayed_group_done_is_idempotent() {
    let handle =
        Controller::spawn(TestbedConfig::new(topologies::fig1a(), 1), policy(1)).unwrap();
    let mut agent = FakeAgent::connect(&handle, 0);
    assert!(handle.wait_ready(1, Duration::from_secs(5)));
    let long = Duration::from_secs(5);
    assert!(agent.read_op("rates_full", long).is_some(), "baseline sync");

    let mut client = TerraClient::connect(handle.addr).unwrap();
    let c1 = client
        .submit_coflow(&[FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(100.0) }], None)
        .unwrap() as u64;
    assert!(agent.read_op("rates_delta", long).is_some(), "rates for the coflow");
    assert!(handle.coflow_remaining_gbit(c1).is_some(), "coflow not in the engine");

    let done = Json::from_pairs([
        ("op", Json::from("group_done")),
        ("coflow", c1.into()),
        ("src", Json::from(0u64)),
        ("dst", Json::from(1u64)),
    ]);
    agent.send(&done);
    let fin = Instant::now() + Duration::from_secs(5);
    while handle.coflow_remaining_gbit(c1).is_some() {
        assert!(Instant::now() < fin, "group_done never completed the coflow");
        std::thread::sleep(Duration::from_millis(10));
    }
    let cct1 = client.wait_done(c1, 5.0).unwrap();
    let rounds = handle.rounds();

    // The replay: same (coflow, src, dst) again, then a sync_request on the
    // same socket — its rates_full reply proves the duplicate was consumed
    // (same-connection ordering) before we assert anything.
    agent.send(&done);
    agent.send(&Json::from_pairs([("op", Json::from("sync_request"))]));
    let full = agent.read_op("rates_full", long).expect("sync after replay");
    assert!(
        delta_keys(&full, "entries").is_empty(),
        "replayed group_done resurrected an entry: {full}"
    );
    assert_eq!(handle.rounds(), rounds, "replayed group_done triggered a spurious round");
    assert!(handle.coflow_remaining_gbit(c1).is_none(), "finished coflow resurrected");
    let cct2 = client.wait_done(c1, 5.0).unwrap();
    assert!(
        (cct2 - cct1).abs() < 1e-9,
        "replay moved the recorded CCT: {cct1} -> {cct2}"
    );

    // Still serviceable: a fresh submission gets an id and an allocation.
    let c2 = client
        .submit_coflow(&[FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: gbit(50.0) }], None)
        .unwrap() as u64;
    assert!(c2 > c1);
    assert!(handle.scheduled_rate(c2) > 0.0, "engine stopped allocating after the replay");
    handle.shutdown();
}
