//! RoundEngine integration: the simulator and a spawned overlay controller
//! replay the same trace (three coflows, bandwidth fluctuations below and
//! above ρ, a link failure) and must produce identical per-coflow rate
//! allocations, because both planes now drive policies exclusively through
//! the shared `engine::RoundEngine`. Also covers the Γ-cache epoch
//! invariants at the engine level.

use terra::api::TerraClient;
use terra::engine::{EngineConfig, RoundEngine, WanReaction};
use terra::net::{topologies, LinkEvent};
use terra::overlay::protocol::FlowSpec;
use terra::overlay::{Controller, TestbedConfig, BYTES_PER_GBPS};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::{CoflowRates, CoflowState, Policy, RoundTrigger};
use terra::sim::{Job, SimConfig, Simulation};

const K: usize = 3;

fn policy() -> Box<dyn Policy> {
    Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, k: K, ..Default::default() }))
}

fn flow(id: u64, s: usize, d: usize, gbit: f64) -> terra::coflow::Flow {
    terra::coflow::Flow { id, src_dc: s, dst_dc: d, volume: gbit }
}

fn spec(id: u64, s: usize, d: usize, gbit: f64) -> FlowSpec {
    FlowSpec { id, src_dc: s, dst_dc: d, bytes: (gbit * BYTES_PER_GBPS) as u64 }
}

fn assert_rates_close(label: &str, sim: &Option<CoflowRates>, ctl: &Option<CoflowRates>) {
    let (Some(a), Some(b)) = (sim, ctl) else {
        // Both sides must agree on whether the coflow has an allocation.
        assert_eq!(sim.is_some(), ctl.is_some(), "{label}: one side has no allocation");
        return;
    };
    assert_eq!(a.len(), b.len(), "{label}: group count");
    for (gi, (ga, gb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ga.len(), gb.len(), "{label}: path count of group {gi}");
        for (pi, (ra, rb)) in ga.iter().zip(gb).enumerate() {
            // GK's demand normalization cancels the remaining-volume
            // perturbation from the controller's wall-clock drain, so both
            // planes solve ulp-identical instances; the tolerance is loose
            // only to absorb float noise, while any real divergence in the
            // shared round logic would show up at full rate magnitude.
            assert!(
                (ra - rb).abs() <= 1e-3 * (1.0 + ra.abs()),
                "{label}: group {gi} path {pi}: sim {ra} vs controller {rb}"
            );
        }
    }
}

/// The trace: c1 = 100 Gbit A→B, c2 = 500 Gbit C→B, c3 = 200 Gbit B→C on
/// the Fig 1a mesh, then a sub-ρ fluctuation (clamp, no round), a super-ρ
/// fluctuation (re-optimize), and a link failure (structural).
#[test]
fn sim_and_controller_allocations_match() {
    // --- Simulator side (virtual time). ---
    let mut sim = Simulation::new(topologies::fig1a(), policy(), SimConfig::default());
    sim.add_job(Job::map_reduce(1, 0.0, 0.0, vec![flow(0, 0, 1, 100.0)]));
    sim.add_job(Job::map_reduce(2, 0.0, 0.0, vec![flow(0, 2, 1, 500.0)]));
    sim.add_job(Job::map_reduce(3, 0.0, 0.0, vec![flow(0, 1, 2, 200.0)]));
    sim.run_until(0.5);
    let sim_initial: Vec<Option<CoflowRates>> = (1..=3).map(|id| sim.allocation(id)).collect();
    sim.add_wan_event(1.0, LinkEvent::SetBandwidth(0, 1, 9.0)); // 10% < rho: clamp
    sim.add_wan_event(2.0, LinkEvent::SetBandwidth(0, 1, 4.0)); // 56% >= rho: reopt
    sim.run_until(2.5);
    let sim_reopt: Vec<Option<CoflowRates>> = (1..=3).map(|id| sim.allocation(id)).collect();
    sim.add_wan_event(3.0, LinkEvent::Fail(0, 1)); // structural
    sim.run_until(3.5);
    let sim_failed: Vec<Option<CoflowRates>> = (1..=3).map(|id| sim.allocation(id)).collect();

    // --- Controller side (wall clock, no agents needed for scheduling). ---
    let handle = Controller::spawn(
        TestbedConfig::new(topologies::fig1a(), K),
        policy(),
    )
    .expect("spawn controller");
    let mut client = TerraClient::connect(handle.addr).expect("connect");
    let mut ids = Vec::new();
    for (i, (s, d, v)) in [(0usize, 1usize, 100.0), (2, 1, 500.0), (1, 2, 200.0)]
        .iter()
        .enumerate()
    {
        let cid = client.submit_coflow(&[spec(i as u64, *s, *d, *v)], None).expect("submit");
        assert!(cid > 0);
        ids.push(cid as u64);
    }
    let ctl_initial: Vec<Option<CoflowRates>> =
        ids.iter().map(|&id| handle.allocation(id)).collect();
    handle.inject_wan_event(LinkEvent::SetBandwidth(0, 1, 9.0));
    handle.inject_wan_event(LinkEvent::SetBandwidth(0, 1, 4.0));
    let ctl_reopt: Vec<Option<CoflowRates>> =
        ids.iter().map(|&id| handle.allocation(id)).collect();
    handle.inject_wan_event(LinkEvent::Fail(0, 1));
    let ctl_failed: Vec<Option<CoflowRates>> =
        ids.iter().map(|&id| handle.allocation(id)).collect();
    handle.shutdown();

    // --- Identical allocations at every checkpoint. ---
    for i in 0..3 {
        assert_rates_close(&format!("initial c{}", i + 1), &sim_initial[i], &ctl_initial[i]);
        assert_rates_close(&format!("post-reopt c{}", i + 1), &sim_reopt[i], &ctl_reopt[i]);
        assert_rates_close(&format!("post-failure c{}", i + 1), &sim_failed[i], &ctl_failed[i]);
    }
    // Sanity: the trace exercised real allocations, not all-empty ones.
    let total: f64 = sim_initial
        .iter()
        .flatten()
        .flat_map(|g| g.iter().flatten())
        .sum();
    assert!(total > 15.0, "initial allocation too small: {total}");
}

/// Γ-cache and component-cache invariants at the engine level: sub-ρ
/// fluctuations must NOT invalidate any cached state (clean components
/// don't even call the policy); qualifying events (≥ ρ or structural)
/// must re-solve.
#[test]
fn gamma_cache_survives_sub_rho_but_not_epoch_bump() {
    let mut e = RoundEngine::new(
        topologies::fig1a(),
        policy(),
        EngineConfig { check_feasibility: true, ..Default::default() },
    );
    for id in 1..=4u64 {
        e.insert(CoflowState::from_coflow(&terra::coflow::Coflow::new(
            id,
            vec![flow(0, (id as usize - 1) % 3, id as usize % 3, 80.0)],
        )));
    }
    e.round(0.0, RoundTrigger::CoflowArrival);
    let cold = e.take_stats();
    assert_eq!(cold.gamma_cache_hits, 0, "first round cannot hit");
    assert!(cold.component_solves >= 1);

    // Sub-ρ fluctuation: no epoch bump, but the clamp rescaled saturated
    // coflows, so their component is dirty — the next round re-optimizes
    // it against current capacities (no ratcheting on stale clamped
    // rates), with every ordering solve answered by the still-warm
    // Γ-cache.
    let epoch0 = e.epoch();
    assert_eq!(e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 9.0)), WanReaction::Clamped);
    assert_eq!(e.epoch(), epoch0);
    e.round(0.1, RoundTrigger::CoflowArrival);
    let warm = e.take_stats();
    assert_eq!(warm.gamma_cache_hits, 4, "all Γ lookups should hit after a sub-ρ event");
    assert!(
        warm.lp_solves < cold.lp_solves,
        "cached round must solve fewer LPs: {} vs {}",
        warm.lp_solves,
        cold.lp_solves
    );

    // Nothing changed since: the follow-up round carries every component
    // forward without a single LP solve.
    e.round(0.15, RoundTrigger::CoflowArrival);
    let clean = e.take_stats();
    assert_eq!(clean.lp_solves, 0, "clean components must not re-solve");
    assert!(clean.component_reuses >= 1);

    // Super-ρ fluctuation: epoch bump + the touched edge dirties its
    // component — every cached Γ is stale, the round is cold again.
    assert_eq!(
        e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 2.0)),
        WanReaction::Reoptimize
    );
    assert_eq!(e.epoch(), epoch0 + 1);
    e.round(0.2, RoundTrigger::WanChange);
    let bumped = e.take_stats();
    assert_eq!(bumped.gamma_cache_hits, 0, "epoch bump must invalidate all Γ entries");
    assert_eq!(bumped.lp_solves, cold.lp_solves, "post-bump round is cold again");
}
