//! Property tests for the open-loop load subsystem:
//!
//! 1. **Alias sampler** — empirical frequencies converge to the histogram
//!    weights, sampling is deterministic per seed, and degenerate
//!    histograms (empty, one-bin, invalid fields) are rejected instead of
//!    silently producing a constant "distribution".
//! 2. **Open-loop inertness** — a disabled generator (`lambda <= 0`) is
//!    indistinguishable from fixed-job-set replay (bit-identical coflow
//!    records), and the same seed yields a byte-identical arrival stream
//!    across runs and across shard counts (the generator never sees the
//!    shard count; the sim records must agree bit-for-bit anyway).

use terra::net::topologies;
use terra::scheduler::terra::TerraPolicy;
use terra::sim::{SimConfig, Simulation};
use terra::util::rng::Pcg32;
use terra::workloads::{
    stream_fingerprint, HistoBin, OpenLoopConfig, OpenLoopGen, RvHisto, WorkloadGen,
    WorkloadKind, WorkloadProfile,
};

fn bins(ws: &[(f64, f64, f64)]) -> Vec<HistoBin> {
    ws.iter().map(|&(lo, hi, w)| HistoBin::new(lo, hi, w)).collect()
}

#[test]
fn alias_frequencies_match_weights() {
    // Four bins with very uneven mass; 40k draws must land within ~1.5
    // absolute percentage points of each weight.
    let h = RvHisto::new(bins(&[
        (0.0, 1.0, 0.5),
        (1.0, 2.0, 0.25),
        (2.0, 4.0, 0.2),
        (4.0, 8.0, 0.05),
    ]))
    .unwrap();
    let mut rng = Pcg32::new(99);
    let n = 40_000;
    let mut counts = [0usize; 4];
    for _ in 0..n {
        let i = h.sample_index(&mut rng);
        counts[i] += 1;
        let v = h.sample(&mut rng);
        assert!(v.is_finite() && (0.0..8.0).contains(&v), "sample {v} out of range");
    }
    for (i, want) in [0.5, 0.25, 0.2, 0.05].iter().enumerate() {
        let got = counts[i] as f64 / n as f64;
        assert!((got - want).abs() < 0.015, "bin {i}: got {got}, want {want}");
    }
}

#[test]
fn alias_sampling_is_deterministic_per_seed() {
    let mk = || RvHisto::new(bins(&[(0.0, 1.0, 1.0), (1.0, 3.0, 2.0), (3.0, 9.0, 3.0)])).unwrap();
    let (ha, hb) = (mk(), mk());
    let mut ra = Pcg32::new(1234);
    let mut rb = Pcg32::new(1234);
    let a: Vec<u64> = (0..1000).map(|_| ha.sample(&mut ra).to_bits()).collect();
    let b: Vec<u64> = (0..1000).map(|_| hb.sample(&mut rb).to_bits()).collect();
    assert_eq!(a, b, "same seed must replay the identical sample sequence");
    let mut rc = Pcg32::new(1235);
    let c: Vec<u64> = (0..1000).map(|_| ha.sample(&mut rc).to_bits()).collect();
    assert_ne!(a, c, "different seeds should not collide on 1000 draws");
}

#[test]
fn alias_rejects_degenerate_histograms() {
    assert!(RvHisto::new(vec![]).is_err(), "empty histogram");
    assert!(RvHisto::new(bins(&[(0.0, 1.0, 1.0)])).is_err(), "one-bin histogram");
    assert!(RvHisto::new(bins(&[(0.0, 1.0, 1.0), (2.0, 1.0, 1.0)])).is_err(), "inverted bin");
    assert!(RvHisto::new(bins(&[(0.0, 1.0, -1.0), (1.0, 2.0, 1.0)])).is_err(), "negative weight");
    assert!(RvHisto::new(bins(&[(0.0, 1.0, 0.0), (1.0, 2.0, 0.0)])).is_err(), "zero total mass");
    assert!(
        RvHisto::new(bins(&[(0.0, f64::NAN, 1.0), (1.0, 2.0, 1.0)])).is_err(),
        "non-finite edge"
    );
}

fn fb_profile() -> WorkloadProfile {
    WorkloadProfile::from_kind(WorkloadKind::Fb, &topologies::swan(), 11, 30)
}

#[test]
fn disabled_generator_is_bit_identical_to_fixed_replay() {
    let wan = topologies::swan();
    let fixed = WorkloadGen::new(WorkloadKind::Fb, 5).jobs(&wan, 12);
    // lambda = 0 disables the generator: no jobs, no RNG draws.
    let olg = OpenLoopGen::new(
        fb_profile(),
        OpenLoopConfig { lambda: 0.0, ..OpenLoopConfig::default() },
    );
    assert!(olg.jobs().is_empty(), "disabled generator must emit nothing");

    let run = |jobs: Vec<terra::sim::Job>| {
        let mut sim =
            Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), SimConfig::default());
        sim.run_jobs(jobs)
    };
    let plain = run(fixed.clone());
    let mut mixed_jobs = fixed.clone();
    mixed_jobs.extend(olg.jobs());
    let mixed = run(mixed_jobs);

    assert_eq!(plain.coflows.len(), mixed.coflows.len());
    for (a, b) in plain.coflows.iter().zip(&mixed.coflows) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        assert_eq!(a.finish.map(f64::to_bits), b.finish.map(f64::to_bits));
        assert_eq!(a.volume.to_bits(), b.volume.to_bits());
    }
    assert_eq!(plain.makespan.to_bits(), mixed.makespan.to_bits());
    // The offered/admitted accounting is live on the fixed path too — it
    // must count every WAN coflow without perturbing the run.
    assert_eq!(plain.offered, plain.coflows.len());
    assert_eq!(plain.offered, plain.admitted + plain.rejected);
    assert_eq!(plain.backlog.len(), plain.offered);
}

#[test]
fn same_seed_means_byte_identical_arrival_stream() {
    let profile = fb_profile();
    let cfg = OpenLoopConfig { lambda: 0.8, horizon_s: 120.0, ..OpenLoopConfig::default() };
    let a = OpenLoopGen::new(profile.clone(), cfg.clone()).jobs();
    let b = OpenLoopGen::new(profile.clone(), cfg.clone()).jobs();
    assert!(!a.is_empty(), "lambda 0.8 over 120 s should produce arrivals");
    assert_eq!(
        stream_fingerprint(&a),
        stream_fingerprint(&b),
        "same seed must replay a byte-identical stream"
    );
    let c = OpenLoopGen::new(profile, OpenLoopConfig { seed: cfg.seed + 1, ..cfg }).jobs();
    assert_ne!(stream_fingerprint(&a), stream_fingerprint(&c), "seed must matter");
}

#[test]
fn arrival_stream_is_identical_across_shard_counts() {
    // The generator is a pure function of (profile, cfg) — it never sees
    // the shard count. Drive the same stream through 1- and 3-shard sims:
    // every recorded arrival (and the records' order) must agree
    // bit-for-bit, so saturation cells at different shard counts face the
    // same offered load.
    let wan = topologies::swan();
    let profile = fb_profile();
    let cfg = OpenLoopConfig { lambda: 0.5, horizon_s: 90.0, ..OpenLoopConfig::default() };
    let jobs = OpenLoopGen::new(profile, cfg).jobs();
    assert!(!jobs.is_empty());
    let run = |shards: usize| {
        let sim_cfg = SimConfig { shards, ..Default::default() };
        let mut sim = Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), sim_cfg);
        sim.run_jobs(jobs.clone())
    };
    let one = run(1);
    let three = run(3);
    assert_eq!(one.coflows.len(), three.coflows.len());
    for (a, b) in one.coflows.iter().zip(&three.coflows) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        assert_eq!(a.volume.to_bits(), b.volume.to_bits());
    }
    assert_eq!(one.offered, three.offered);
    assert_eq!(one.admitted, three.admitted);
}
