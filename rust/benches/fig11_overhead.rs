//! Bench: regenerate Figures 3 + 11 / §6.6 (scheduling overhead: Terra vs
//! Rapier per topology; LPs and milliseconds per round).
use terra::experiments::fig11_overhead;
use terra::util::bench::{quick_mode, report, time_n, Table};

fn main() {
    let jobs = if quick_mode() { 12 } else { 100 };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = fig11_overhead(jobs, 42));
    report("fig11_overhead", &t);
    let mut tab = Table::new(&["topology", "policy", "rounds", "LPs/round", "ms/round", "vs terra"]);
    let mut terra_ms = std::collections::HashMap::new();
    for r in &rows {
        if r.policy == "terra" {
            terra_ms.insert(r.topology.clone(), r.ms_per_round);
        }
    }
    for r in &rows {
        let ratio = r.ms_per_round / terra_ms.get(&r.topology).copied().unwrap_or(1.0).max(1e-9);
        tab.row(&[
            r.topology.clone(),
            r.policy.clone(),
            r.rounds.to_string(),
            format!("{:.1}", r.lp_per_round),
            format!("{:.3}", r.ms_per_round),
            format!("{:.1}x", ratio),
        ]);
    }
    tab.print("Figures 3+11 (paper: Terra 74ms/round SWAN, 589ms ATT; Rapier 26.2x/29.1x slower)");
}
