//! Bench: component-decomposed delta rounds (§6.6 scalability, extended).
//!
//! Sweeps the active-coflow count 100 → 10 000 on all three evaluation
//! topologies with a pod-localized workload — single-group coflows between
//! adjacent datacenter pairs at k = 1, so every coflow pins to its direct
//! edge and the active set factors into one component per edge-sharing
//! class — and times steady-state scheduling rounds (one coflow arrival
//! between rounds, the canonical trigger) in three modes:
//!
//! - **cold**: monolithic per-round re-solve of everything (pre-incremental
//!   behavior),
//! - **warm**: Γ-cache + GK warm starts, but still one monolithic solve of
//!   the full active set per round (PR 1 behavior),
//! - **component**: the default — only the arrival's component re-solves,
//!   every other component's allocation is carried forward.
//!
//! Emits `BENCH_component_scaling.json` (p50/p99 round latency, LP
//! solves/round, component solves+reuses/round, and the p99 speedup of
//! component-cached over cold monolithic per scale).

use std::time::Instant;
use terra::coflow::{Coflow, Flow};
use terra::engine::{EngineConfig, RoundEngine};
use terra::net::{topologies, Wan};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::{CoflowState, RoundTrigger};
use terra::util::bench::{quick_mode, Table};
use terra::util::json::Json;
use terra::util::rng::Pcg32;
use terra::util::stats;

/// Pod-local coflow between one adjacent (directly linked) pair.
fn mk_state(id: u64, pairs: &[(usize, usize)], rng: &mut Pcg32) -> CoflowState {
    let (s, d) = pairs[rng.below(pairs.len())];
    let mut st = CoflowState::from_coflow(&Coflow::new(
        id,
        vec![Flow { id: 0, src_dc: s, dst_dc: d, volume: rng.uniform(50.0, 4000.0) }],
    ));
    st.admitted = true;
    st
}

#[derive(Clone, Copy)]
enum Mode {
    Cold,
    Warm,
    Component,
}

impl Mode {
    fn config(self) -> EngineConfig {
        match self {
            Mode::Cold => {
                EngineConfig { check_feasibility: false, cold: true, ..Default::default() }
            }
            Mode::Warm => {
                EngineConfig { check_feasibility: false, decompose: false, ..Default::default() }
            }
            Mode::Component => EngineConfig { check_feasibility: false, ..Default::default() },
        }
    }
}

struct ModeResult {
    p50_ms: f64,
    p99_ms: f64,
    lp_per_round: f64,
    gamma_hits_per_round: f64,
    comp_solves_per_round: f64,
    comp_reuses_per_round: f64,
}

/// Time `rounds` steady-state rounds at `n` active coflows, each preceded
/// by one arrival. The populate round is untimed in every mode.
fn bench_mode(wan: &Wan, n: usize, mode: Mode, rounds: usize) -> ModeResult {
    let policy = TerraPolicy::new(TerraConfig { k: 1, ..Default::default() });
    let mut engine = RoundEngine::new(wan.clone(), Box::new(policy), mode.config());
    let pairs: Vec<(usize, usize)> = wan.links().iter().map(|l| (l.src, l.dst)).collect();
    let mut rng = Pcg32::new(0xC0135 + n as u64);
    for i in 0..n {
        let st = mk_state(i as u64 + 1, &pairs, &mut rng);
        engine.insert(st);
    }
    engine.round(0.0, RoundTrigger::Initial);
    engine.take_stats(); // drop populate-round counters
    let mut lat = Vec::with_capacity(rounds);
    let mut now = 0.0;
    for r in 0..rounds {
        engine.drain(0.05, 0.0);
        now += 0.05;
        let st = mk_state((n + r) as u64 + 1, &pairs, &mut rng);
        engine.insert(st);
        let t0 = Instant::now();
        engine.round(now, RoundTrigger::CoflowArrival);
        lat.push(t0.elapsed().as_secs_f64());
    }
    let st = engine.take_stats();
    let r = rounds as f64;
    ModeResult {
        p50_ms: 1e3 * stats::percentile(&lat, 50.0),
        p99_ms: 1e3 * stats::percentile(&lat, 99.0),
        lp_per_round: st.lp_solves as f64 / r,
        gamma_hits_per_round: st.gamma_cache_hits as f64 / r,
        comp_solves_per_round: st.component_solves as f64 / r,
        comp_reuses_per_round: st.component_reuses as f64 / r,
    }
}

fn mode_json(m: &ModeResult) -> Json {
    Json::from_pairs([
        ("p50_ms", Json::from(m.p50_ms)),
        ("p99_ms", m.p99_ms.into()),
        ("lp_solves_per_round", m.lp_per_round.into()),
        ("gamma_cache_hits_per_round", m.gamma_hits_per_round.into()),
        ("component_solves_per_round", m.comp_solves_per_round.into()),
        ("component_reuses_per_round", m.comp_reuses_per_round.into()),
    ])
}

fn main() {
    let quick = quick_mode();
    let scales: Vec<usize> =
        if quick { vec![100, 500, 2000] } else { vec![100, 500, 2000, 10_000] };
    let rounds = if quick { 4 } else { 8 };
    let topos: Vec<(&str, Wan)> = vec![
        ("swan", topologies::swan()),
        ("gscale", topologies::gscale()),
        ("att", topologies::att()),
    ];
    let mut topo_docs = Vec::new();
    for (tname, wan) in &topos {
        let mut tab = Table::new(&[
            "active",
            "cold p99",
            "warm p99",
            "comp p99",
            "p99 speedup vs cold",
            "comp LPs/rd",
            "reuses/rd",
        ]);
        let mut scale_docs = Vec::new();
        for &n in &scales {
            let results: Vec<ModeResult> = [Mode::Cold, Mode::Warm, Mode::Component]
                .into_iter()
                .map(|m| bench_mode(wan, n, m, rounds))
                .collect();
            let cold_p99 = results[0].p99_ms;
            let comp = &results[2];
            let speedup = if comp.p99_ms > 0.0 { cold_p99 / comp.p99_ms } else { f64::INFINITY };
            tab.row(&[
                n.to_string(),
                format!("{cold_p99:.2}ms"),
                format!("{:.2}ms", results[1].p99_ms),
                format!("{:.2}ms", comp.p99_ms),
                format!("{speedup:.1}x"),
                format!("{:.1}", comp.lp_per_round),
                format!("{:.1}", comp.comp_reuses_per_round),
            ]);
            let doc = Json::from_pairs([
                ("active_coflows", Json::from(n)),
                ("p99_speedup_component_vs_cold", speedup.into()),
                ("cold", mode_json(&results[0])),
                ("warm", mode_json(&results[1])),
                ("component", mode_json(&results[2])),
            ]);
            scale_docs.push(doc);
        }
        tab.print(&format!("{tname}: steady-state round latency by mode"));
        topo_docs.push(Json::from_pairs([
            ("topology", Json::from(*tname)),
            ("scales", Json::Arr(scale_docs)),
        ]));
    }
    let doc = Json::from_pairs([
        ("workload", Json::from("pod-local single-group coflows on adjacent pairs, k=1")),
        ("rounds_timed", rounds.into()),
        ("arrivals_per_round", 1u64.into()),
        ("topologies", Json::Arr(topo_docs)),
    ]);
    let path = "BENCH_component_scaling.json";
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
