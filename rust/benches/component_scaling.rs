//! Bench: component-decomposed delta rounds (§6.6 scalability, extended).
//!
//! Sweeps the active-coflow count 100 → 10 000 on all three evaluation
//! topologies with a pod-localized workload — single-group coflows between
//! adjacent datacenter pairs at k = 1, so every coflow pins to its direct
//! edge and the active set factors into one component per edge-sharing
//! class — and times steady-state scheduling rounds (one coflow arrival
//! between rounds, the canonical trigger) across:
//!
//! - **cold**: monolithic per-round re-solve of everything (pre-incremental
//!   behavior),
//! - **warm**: Γ-cache + GK warm starts, but still one monolithic solve of
//!   the full active set per round (PR 1 behavior),
//! - **component × solver-repr × workers**: decomposed delta rounds (PR 3)
//!   on the jagged or flat solver representation, with 1 / 2 / all-core
//!   parallel component solves. `solver_repr = jagged, workers = 1` is the
//!   PR 3 baseline; `flat` + all cores is the current default. All
//!   component combos produce bit-identical allocations (property-tested)
//!   — only latency differs.
//!
//! Emits `BENCH_component_scaling.json` (p50/p99 round latency, LP
//! solves/round, component solves+reuses/round per combo, plus the p50/p99
//! speedups of the default flat+parallel configuration over both the cold
//! monolithic and the PR 3 jagged-sequential baselines).

use std::time::Instant;
use terra::coflow::{Coflow, Flow};
use terra::engine::{default_workers, EngineConfig, RoundEngine};
use terra::lp::SolverRepr;
use terra::net::{topologies, Wan};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::{CoflowState, RoundTrigger};
use terra::util::bench::{quick_mode, Table};
use terra::util::json::Json;
use terra::util::rng::Pcg32;
use terra::util::stats;

/// Pod-local coflow between one adjacent (directly linked) pair.
fn mk_state(id: u64, pairs: &[(usize, usize)], rng: &mut Pcg32) -> CoflowState {
    let (s, d) = pairs[rng.below(pairs.len())];
    let mut st = CoflowState::from_coflow(&Coflow::new(
        id,
        vec![Flow { id: 0, src_dc: s, dst_dc: d, volume: rng.uniform(50.0, 4000.0) }],
    ));
    st.admitted = true;
    st
}

#[derive(Clone, Copy)]
struct ModeSpec {
    /// JSON key / table label.
    name: &'static str,
    cold: bool,
    decompose: bool,
    repr: SolverRepr,
    workers: usize,
}

struct ModeResult {
    p50_ms: f64,
    p99_ms: f64,
    lp_per_round: f64,
    gamma_hits_per_round: f64,
    comp_solves_per_round: f64,
    comp_reuses_per_round: f64,
}

/// Time `rounds` steady-state rounds at `n` active coflows, each preceded
/// by one arrival. The populate round is untimed in every mode.
fn bench_mode(wan: &Wan, n: usize, spec: ModeSpec, rounds: usize) -> ModeResult {
    let policy = TerraPolicy::new(TerraConfig { k: 1, repr: spec.repr, ..Default::default() });
    let cfg = EngineConfig {
        check_feasibility: false,
        cold: spec.cold,
        decompose: spec.decompose,
        workers: spec.workers,
        ..Default::default()
    };
    let mut engine = RoundEngine::new(wan.clone(), Box::new(policy), cfg);
    let pairs: Vec<(usize, usize)> = wan.links().iter().map(|l| (l.src, l.dst)).collect();
    let mut rng = Pcg32::new(0xC0135 + n as u64);
    for i in 0..n {
        let st = mk_state(i as u64 + 1, &pairs, &mut rng);
        engine.insert(st);
    }
    engine.round(0.0, RoundTrigger::Initial);
    engine.take_stats(); // drop populate-round counters
    let mut lat = Vec::with_capacity(rounds);
    let mut now = 0.0;
    for r in 0..rounds {
        engine.drain(0.05, 0.0);
        now += 0.05;
        let st = mk_state((n + r) as u64 + 1, &pairs, &mut rng);
        engine.insert(st);
        let t0 = Instant::now();
        engine.round(now, RoundTrigger::CoflowArrival);
        lat.push(t0.elapsed().as_secs_f64());
    }
    let st = engine.take_stats();
    let r = rounds as f64;
    ModeResult {
        p50_ms: 1e3 * stats::percentile(&lat, 50.0),
        p99_ms: 1e3 * stats::percentile(&lat, 99.0),
        lp_per_round: st.lp_solves as f64 / r,
        gamma_hits_per_round: st.gamma_cache_hits as f64 / r,
        comp_solves_per_round: st.component_solves as f64 / r,
        comp_reuses_per_round: st.component_reuses as f64 / r,
    }
}

fn mode_json(spec: &ModeSpec, m: &ModeResult) -> Json {
    Json::from_pairs([
        ("mode", Json::from(spec.name)),
        (
            "solver_repr",
            Json::from(match spec.repr {
                SolverRepr::Jagged => "jagged",
                SolverRepr::Flat => "flat",
            }),
        ),
        ("workers", Json::from(spec.workers)),
        ("p50_ms", Json::from(m.p50_ms)),
        ("p99_ms", m.p99_ms.into()),
        ("lp_solves_per_round", m.lp_per_round.into()),
        ("gamma_cache_hits_per_round", m.gamma_hits_per_round.into()),
        ("component_solves_per_round", m.comp_solves_per_round.into()),
        ("component_reuses_per_round", m.comp_reuses_per_round.into()),
    ])
}

fn main() {
    let quick = quick_mode();
    let scales: Vec<usize> =
        if quick { vec![100, 500, 2000] } else { vec![100, 500, 2000, 10_000] };
    let rounds = if quick { 4 } else { 8 };
    let all = default_workers();
    let mut workers_axis = vec![1usize, 2, all];
    workers_axis.sort_unstable();
    workers_axis.dedup();
    // Widest configuration actually in the matrix — equals `all` except on
    // 1-core machines (where the axis still includes workers=2 so a
    // parallel data point exists); labels and speedups use this value so
    // they always describe the measured config.
    let w_max = *workers_axis.last().unwrap();
    let topos: Vec<(&str, Wan)> = vec![
        ("swan", topologies::swan()),
        ("gscale", topologies::gscale()),
        ("att", topologies::att()),
    ];
    // Monolithic baselines + the component repr × workers matrix. The
    // first component entry (jagged, 1 worker) is exactly the PR 3
    // configuration; the last (flat, all cores) is the current default.
    let mut specs: Vec<ModeSpec> = vec![
        ModeSpec {
            name: "cold",
            cold: true,
            decompose: true,
            repr: SolverRepr::Flat,
            workers: 1,
        },
        ModeSpec {
            name: "warm",
            cold: false,
            decompose: false,
            repr: SolverRepr::Flat,
            workers: 1,
        },
    ];
    for repr in [SolverRepr::Jagged, SolverRepr::Flat] {
        for &w in &workers_axis {
            specs.push(ModeSpec {
                name: match repr {
                    SolverRepr::Jagged => "component-jagged",
                    SolverRepr::Flat => "component-flat",
                },
                cold: false,
                decompose: true,
                repr,
                workers: w,
            });
        }
    }
    let pr3_idx = 2; // component-jagged, workers = 1
    let default_idx = specs.len() - 1; // component-flat, workers = w_max

    let mut topo_docs = Vec::new();
    for (tname, wan) in &topos {
        let mut tab = Table::new(&[
            "active",
            "cold p50",
            "jagged×1 p50 (PR3)",
            "flat×1 p50",
            &format!("flat×{w_max} p50"),
            "speedup vs PR3",
            "speedup vs cold",
            "reuses/rd",
        ]);
        let mut scale_docs = Vec::new();
        for &n in &scales {
            let results: Vec<ModeResult> =
                specs.iter().map(|&s| bench_mode(wan, n, s, rounds)).collect();
            let cold = &results[0];
            let pr3 = &results[pr3_idx];
            let flat_seq = &results[pr3_idx + workers_axis.len()];
            let flat_par = &results[default_idx];
            let sp_pr3 =
                if flat_par.p50_ms > 0.0 { pr3.p50_ms / flat_par.p50_ms } else { f64::INFINITY };
            let sp_cold =
                if flat_par.p50_ms > 0.0 { cold.p50_ms / flat_par.p50_ms } else { f64::INFINITY };
            tab.row(&[
                n.to_string(),
                format!("{:.2}ms", cold.p50_ms),
                format!("{:.2}ms", pr3.p50_ms),
                format!("{:.2}ms", flat_seq.p50_ms),
                format!("{:.2}ms", flat_par.p50_ms),
                format!("{sp_pr3:.2}x"),
                format!("{sp_cold:.1}x"),
                format!("{:.1}", flat_par.comp_reuses_per_round),
            ]);
            let modes: Vec<Json> =
                specs.iter().zip(&results).map(|(s, m)| mode_json(s, m)).collect();
            let doc = Json::from_pairs([
                ("active_coflows", Json::from(n)),
                ("p50_speedup_flat_parallel_vs_pr3", sp_pr3.into()),
                (
                    "p99_speedup_flat_parallel_vs_pr3",
                    (if flat_par.p99_ms > 0.0 {
                        pr3.p99_ms / flat_par.p99_ms
                    } else {
                        f64::INFINITY
                    })
                    .into(),
                ),
                ("p99_speedup_component_vs_cold", {
                    let comp = flat_par;
                    (if comp.p99_ms > 0.0 { cold.p99_ms / comp.p99_ms } else { f64::INFINITY })
                        .into()
                }),
                ("cold", mode_json(&specs[0], cold)),
                ("warm", mode_json(&specs[1], &results[1])),
                ("component", mode_json(&specs[default_idx], flat_par)),
                ("component_modes", Json::Arr(modes)),
            ]);
            scale_docs.push(doc);
        }
        tab.print(&format!("{tname}: steady-state round p50 latency by solver repr × workers"));
        topo_docs.push(Json::from_pairs([
            ("topology", Json::from(*tname)),
            ("scales", Json::Arr(scale_docs)),
        ]));
    }
    let doc = Json::from_pairs([
        ("workload", Json::from("pod-local single-group coflows on adjacent pairs, k=1")),
        ("rounds_timed", rounds.into()),
        ("arrivals_per_round", 1u64.into()),
        ("available_workers", all.into()),
        ("topologies", Json::Arr(topo_docs)),
    ]);
    let path = "BENCH_component_scaling.json";
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
