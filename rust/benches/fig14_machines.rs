//! Bench: regenerate Figure 14 (machines per DC: computation vs
//! communication share of JCT).
use terra::experiments::fig14_machines;
use terra::util::bench::{quick_mode, report, time_n, Table};

fn main() {
    let jobs = if quick_mode() { 15 } else { 150 };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = fig14_machines(jobs, 42));
    report("fig14_machines", &t);
    let mut tab = Table::new(&["machines/DC", "FoI avg JCT"]);
    for r in &rows {
        tab.row(&[r.machines.to_string(), format!("{:.2}x", r.foi_avg_jct)]);
    }
    tab.print("Figure 14: FoI grows with machines (comm dominates)");
}
