//! Bench: the capacity-estimation sweep — dynamics profiles × estimators
//! (oracle / EWMA / Kalman-lite / hold-down) on SWAN + BigBench with
//! deadline-bearing coflows, reporting per-estimator estimation error
//! (MAPE), stale-reaction latency, and CCT inflation vs the oracle.
//! Results are written to `BENCH_estimation.json` (same schema as
//! `terra sweep --estimation`).

use terra::experiments::{estimation_json, estimation_sweep, EstimationSweepConfig};
use terra::util::bench::{quick_mode, report, time_n, Table};

fn main() {
    let cfg = EstimationSweepConfig {
        jobs: if quick_mode() { 2 } else { 4 },
        horizon_s: if quick_mode() { 160.0 } else { 240.0 },
        ..Default::default()
    };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = estimation_sweep(&cfg));
    report("estimation_sweep", &t);

    let mut tab = Table::new(&[
        "profile", "estimator", "avg CCT", "vs oracle", "MAPE", "react s", "stale", "probes",
        "met",
    ]);
    for r in &rows {
        tab.row(&[
            r.profile.clone(),
            r.estimator.clone(),
            format!("{:.1}s", r.avg_cct),
            format!("{:.2}x", r.cct_vs_oracle),
            format!("{:.1}%", r.est_mape * 100.0),
            format!("{:.2}", r.stale_reaction_s_avg),
            format!("{}/{}", r.stale_resolved, r.stale_events),
            r.est_probes.to_string(),
            format!("{:.0}%", r.deadline_met * 100.0),
        ]);
    }
    tab.print("Estimation sweep: scheduling on beliefs vs the oracle");

    let json = format!("{}\n", estimation_json(&cfg, &rows));
    std::fs::write("BENCH_estimation.json", json).expect("write BENCH_estimation.json");
    println!("wrote BENCH_estimation.json ({} rows)", rows.len());
}
