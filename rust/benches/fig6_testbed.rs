//! Bench: regenerate Figure 6 + Table 2 (testbed-style JCT/CCT/utilization
//! improvements on SWAN) — scaled down under `cargo bench`, full scale with
//! TERRA_BENCH_FULL=1.
use terra::experiments::fig6_testbed;
use terra::util::bench::{quick_mode, report, time_n, Table};

fn main() {
    let jobs = if quick_mode() { 12 } else { 400 };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = fig6_testbed(jobs, 42));
    report("fig6_testbed", &t);
    let mut tab = Table::new(&["workload", "FoI avg JCT", "FoI p95", "FoI CCT", "FoI util"]);
    for r in &rows {
        tab.row(&[
            r.workload.clone(),
            format!("{:.2}x", r.foi_avg_jct),
            format!("{:.2}x", r.foi_p95_jct),
            format!("{:.2}x", r.foi_avg_cct),
            format!("{:.2}x", r.foi_util),
        ]);
    }
    tab.print("Figure 6 + Table 2 (paper: avg 1.55-3.43x, p95 2.12-8.49x, util 1.32-1.76x)");
    // Fig 7 CDF sample points (p10..p90 of the JCT distribution).
    for r in &rows {
        let e = terra::util::stats::Ecdf::new(r.terra_jcts.clone());
        let b = terra::util::stats::Ecdf::new(r.perflow_jcts.clone());
        println!(
            "fig7[{}]: terra p50={:.0}s p90={:.0}s | per-flow p50={:.0}s p90={:.0}s",
            r.workload,
            terra::util::stats::percentile(&r.terra_jcts, 50.0),
            terra::util::stats::percentile(&r.terra_jcts, 90.0),
            terra::util::stats::percentile(&r.perflow_jcts, 50.0),
            terra::util::stats::percentile(&r.perflow_jcts, 90.0),
        );
        let _ = (e, b);
    }
}
