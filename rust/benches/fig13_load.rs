//! Bench: regenerate Figure 13 (arrival-rate/load scaling on SWAN).
use terra::experiments::fig13_load;
use terra::util::bench::{quick_mode, report, time_n, Table};

fn main() {
    let jobs = if quick_mode() { 15 } else { 150 };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = fig13_load(jobs, 42));
    report("fig13_load", &t);
    let mut tab = Table::new(&["arrival scale", "FoI avg JCT"]);
    for r in &rows {
        tab.row(&[format!("{:.1}x", r.arrival_scale), format!("{:.2}x", r.foi_avg_jct)]);
    }
    tab.print("Figure 13: FoI grows with load");
}
