//! Bench: regenerate Figure 13 (arrival-rate/load scaling on SWAN), plus a
//! round-latency microbenchmark of the shared `RoundEngine` that tracks the
//! incremental re-optimization speedup across PRs: p50/p99 round latency
//! and LP solves per round at 100/500/2000 active coflows, cold (per-round
//! re-solve of every standalone Γ) vs Γ-cached. Results are written to
//! `BENCH_round_latency.json`.
use terra::engine::{EngineConfig, RoundEngine};
use terra::experiments::fig13_load;
use terra::net::{topologies, Wan};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::{CoflowState, RoundTrigger};
use terra::util::bench::{quick_mode, report, time_n, Table};
use terra::util::json::Json;
use terra::util::rng::Pcg32;
use terra::util::stats;
use std::time::Instant;

fn main() {
    let jobs = if quick_mode() { 15 } else { 150 };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = fig13_load(jobs, 42));
    report("fig13_load", &t);
    let mut tab = Table::new(&["arrival scale", "FoI avg JCT"]);
    for r in &rows {
        tab.row(&[format!("{:.1}x", r.arrival_scale), format!("{:.2}x", r.foi_avg_jct)]);
    }
    tab.print("Figure 13: FoI grows with load");

    round_latency_bench();
}

/// Random active coflows over the SWAN sites (1–3 FlowGroups each).
fn mk_states(wan: &Wan, n: usize, seed: u64) -> Vec<CoflowState> {
    let mut rng = Pcg32::new(seed);
    let nodes = wan.num_nodes();
    (0..n)
        .map(|i| {
            let flows = (0..1 + rng.below(3))
                .map(|f| {
                    let s = rng.below(nodes);
                    let mut d = rng.below(nodes);
                    while d == s {
                        d = rng.below(nodes);
                    }
                    terra::coflow::Flow {
                        id: f as u64,
                        src_dc: s,
                        dst_dc: d,
                        volume: rng.uniform(10.0, 400.0),
                    }
                })
                .collect();
            let mut st =
                CoflowState::from_coflow(&terra::coflow::Coflow::new(i as u64 + 1, flows));
            st.admitted = true;
            st
        })
        .collect()
}

struct ModeResult {
    p50_ms: f64,
    p99_ms: f64,
    lp_per_round: f64,
    gamma_hits_per_round: f64,
}

/// Time steady-state rounds at `n` active coflows. Both modes get one
/// untimed populate round first, so "cached" measures warm steady state
/// (which, with nothing changing between rounds, now reuses every
/// component's allocation outright — see `benches/component_scaling.rs`
/// for the arrival-churn variant that isolates the decomposition win) and
/// "cold" measures the pre-incremental per-round cost.
fn bench_mode(n: usize, cold: bool, rounds: usize) -> ModeResult {
    let wan = topologies::swan();
    let states = mk_states(&wan, n, 0xF13 + n as u64);
    let policy = TerraPolicy::new(TerraConfig::default());
    let mut engine = RoundEngine::new(
        wan,
        Box::new(policy),
        EngineConfig { check_feasibility: false, cold, ..Default::default() },
    );
    for st in states {
        engine.insert(st);
    }
    engine.round(0.0, RoundTrigger::Initial);
    engine.take_stats(); // drop populate-round counters
    let mut lat = Vec::with_capacity(rounds);
    let mut now = 0.0;
    for _ in 0..rounds {
        engine.drain(0.05, 0.0);
        now += 0.05;
        let t0 = Instant::now();
        engine.round(now, RoundTrigger::CoflowArrival);
        lat.push(t0.elapsed().as_secs_f64());
    }
    let st = engine.take_stats();
    ModeResult {
        p50_ms: 1e3 * stats::percentile(&lat, 50.0),
        p99_ms: 1e3 * stats::percentile(&lat, 99.0),
        lp_per_round: st.lp_solves as f64 / rounds as f64,
        gamma_hits_per_round: st.gamma_cache_hits as f64 / rounds as f64,
    }
}

fn mode_json(m: &ModeResult) -> Json {
    Json::from_pairs([
        ("p50_ms", Json::from(m.p50_ms)),
        ("p99_ms", m.p99_ms.into()),
        ("lp_solves_per_round", m.lp_per_round.into()),
        ("gamma_cache_hits_per_round", m.gamma_hits_per_round.into()),
    ])
}

fn round_latency_bench() {
    let rounds = if quick_mode() { 3 } else { 10 };
    let scales: &[usize] = &[100, 500, 2000];
    let mut tab = Table::new(&[
        "active", "cold p50", "cold p99", "cold LPs/rd", "cached p50", "cached p99",
        "cached LPs/rd",
    ]);
    let mut out_scales = Vec::new();
    for &n in scales {
        let cold = bench_mode(n, true, rounds);
        let cached = bench_mode(n, false, rounds);
        tab.row(&[
            n.to_string(),
            format!("{:.1}ms", cold.p50_ms),
            format!("{:.1}ms", cold.p99_ms),
            format!("{:.1}", cold.lp_per_round),
            format!("{:.1}ms", cached.p50_ms),
            format!("{:.1}ms", cached.p99_ms),
            format!("{:.1}", cached.lp_per_round),
        ]);
        out_scales.push(Json::from_pairs([
            ("active_coflows", Json::from(n)),
            ("cold", mode_json(&cold)),
            ("cached", mode_json(&cached)),
        ]));
    }
    tab.print("RoundEngine steady-state round latency (cold vs Γ-cached)");
    let doc = Json::from_pairs([
        ("topology", Json::from("swan")),
        ("rounds_timed", rounds.into()),
        // Both modes run the default flat-CSR solver; this workload keeps
        // the whole active set edge-connected (k = 15 on SWAN), so rounds
        // are one component and the workers axis is a no-op here — see
        // benches/component_scaling.rs for the repr × workers matrix.
        ("solver_repr", Json::from("flat")),
        ("workers", terra::engine::default_workers().into()),
        ("scales", Json::Arr(out_scales)),
    ]);
    let path = "BENCH_round_latency.json";
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
