//! Bench: the open-loop saturation sweep — ramp + bisect the arrival rate
//! to the max-sustainable-coflows/s knee per ⟨topology, dynamics profile,
//! policy, shard count⟩ cell, with the estimation-quality column
//! (MAPE / stale-reaction latency) measured at the knee. Results are
//! written to `BENCH_saturation.json` (same schema as
//! `terra sweep --saturation`).

use terra::experiments::{saturation_json, saturation_sweep, SaturationSweepConfig};
use terra::util::bench::{quick_mode, report, time_n, Table};

fn main() {
    let cfg = if quick_mode() {
        SaturationSweepConfig {
            shard_counts: vec![1, 2],
            warmup_s: 10.0,
            measure_s: 30.0,
            drain_s: 20.0,
            profile_samples: 20,
            max_lambda: 0.8,
            bisect_iters: 2,
            streams: 2,
            ..SaturationSweepConfig::quick()
        }
    } else {
        SaturationSweepConfig::quick()
    };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = saturation_sweep(&cfg));
    report("saturation_sweep", &t);

    let mut tab = Table::new(&[
        "topology", "profile", "policy", "shards", "knee/s", "sat", "evals", "p99 slow", "miss",
        "MAPE",
    ]);
    for r in &rows {
        let sat = if r.saturated { "y" } else { ">=cap" };
        tab.row(&[
            r.topology.clone(),
            r.profile.clone(),
            r.policy.clone(),
            r.shards.to_string(),
            format!("{:.3}", r.knee_lambda),
            sat.to_string(),
            r.evals.to_string(),
            format!("{:.1}", r.p99_slowdown),
            format!("{:.0}%", r.miss_rate * 100.0),
            format!("{:.1}%", r.est_mape * 100.0),
        ]);
    }
    tab.print("Saturation sweep: open-loop knee per cell");

    let json = format!("{}\n", saturation_json(&cfg, &rows));
    std::fs::write("BENCH_saturation.json", json).expect("write BENCH_saturation.json");
    println!("wrote BENCH_saturation.json ({} rows)", rows.len());
}
