//! Bench: regenerate Figure 8 (deadline-sensitive coflows).
use terra::experiments::fig8_deadlines;
use terra::util::bench::{quick_mode, report, time_n, Table};

fn main() {
    let jobs = if quick_mode() { 10 } else { 200 };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = fig8_deadlines(jobs, 42, "per-flow"));
    report("fig8_deadlines", &t);
    let mut tab = Table::new(&["d", "terra met", "per-flow met", "ratio"]);
    for r in &rows {
        tab.row(&[
            format!("{:.0}", r.d),
            format!("{:.0}%", r.terra_met * 100.0),
            format!("{:.0}%", r.baseline_met * 100.0),
            format!("{:.2}x", r.terra_met / r.baseline_met.max(1e-9)),
        ]);
    }
    tab.print("Figure 8 (paper: 2.82-4.29x testbed / 1.07-2.31x sim more deadlines met)");
}
