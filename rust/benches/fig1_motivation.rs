//! Bench: regenerate Figure 1 (motivating example) and time the run.
use terra::experiments::fig1_motivation;
use terra::util::bench::{report, time_n, Table};

fn main() {
    let mut rows = Vec::new();
    let t = time_n(1, 5, || rows = fig1_motivation());
    report("fig1_motivation", &t);
    let mut tab = Table::new(&["policy", "avg CCT (s)", "paper (s)"]);
    let paper = [("per-flow", 14.0), ("multipath", 10.6), ("varys", 12.0), ("terra", 7.15)];
    for (name, cct) in &rows {
        let p = paper.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(0.0);
        tab.row(&[name.clone(), format!("{cct:.2}"), format!("{p:.2}")]);
    }
    tab.print("Figure 1: scheduling-routing co-optimization");
}
