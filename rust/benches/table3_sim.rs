//! Bench: regenerate Tables 3 + 4 (+ §6.3 slowdown & volume-correlation):
//! Terra vs the five baselines across <topology, workload>. Scaled down by
//! default; TERRA_BENCH_FULL=1 and the `terra reproduce --table3` CLI run
//! the full 400-job version.
use terra::experiments::table3;
use terra::util::bench::{quick_mode, report, time_n, Table};

fn main() {
    let (jobs, filter) = if quick_mode() { (8, Some("swan")) } else { (400, None) };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = table3(jobs, 42, filter));
    report("table3_sim", &t);
    let mut tab =
        Table::new(&["topology", "workload", "baseline", "FoI avg", "FoI p95", "slowdown T/B"]);
    for r in &rows {
        tab.row(&[
            r.topology.clone(),
            r.workload.clone(),
            r.baseline.clone(),
            format!("{:.2}x", r.foi_avg_jct),
            format!("{:.2}x", r.foi_p95_jct),
            format!("{:.2}/{:.2}", r.terra_slowdown, r.baseline_slowdown),
        ]);
    }
    tab.print("Table 3 (paper: 1.04-2.53x SWAN ... 1.52-26.97x ATT)");
    let wins = rows.iter().filter(|r| r.foi_avg_jct > 1.0).count();
    println!("terra wins {wins}/{} cells on avg JCT", rows.len());
}
