//! Bench: sharded, pipelined control plane at 10k → 1M active coflows.
//!
//! Sweeps the active-coflow count on all three evaluation topologies with
//! the pod-localized workload (single-group coflows between adjacent
//! pairs, k = 1, no deadlines — admission is O(1) and every coflow pins to
//! its direct edge), comparing `shards = 1` (the PR 4/5 engine, the
//! property-pinned baseline) against multi-shard front-ends that pipeline
//! partition → solve → enforce across shards:
//!
//! - **admitted/s**: batched-admission throughput — routing + adoption for
//!   the whole population,
//! - **rounds/s and p50/p99 decision latency**: steady-state scheduling
//!   rounds, each triggered by one arrival (insert + round timed
//!   together: the arrival-to-rates decision path).
//!
//! All shard counts produce bit-identical allocations (property-pinned by
//! `tests/prop_sharded.rs`); only throughput and latency differ. Emits
//! `BENCH_control_scale.json`. Quick mode stops at 100k active coflows;
//! `TERRA_BENCH_FULL=1` extends the sweep to 1M.

use std::time::Instant;
use terra::coflow::{Coflow, Flow};
use terra::engine::{default_workers, EngineConfig, ShardedEngine};
use terra::net::{topologies, Wan};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::scheduler::{CoflowState, RoundTrigger};
use terra::util::bench::{quick_mode, Table};
use terra::util::json::Json;
use terra::util::rng::Pcg32;
use terra::util::stats;

/// Pod-local coflow between one adjacent (directly linked) pair.
fn mk_state(id: u64, pairs: &[(usize, usize)], rng: &mut Pcg32) -> CoflowState {
    let (s, d) = pairs[rng.below(pairs.len())];
    let mut st = CoflowState::from_coflow(&Coflow::new(
        id,
        vec![Flow { id: 0, src_dc: s, dst_dc: d, volume: rng.uniform(50.0, 4000.0) }],
    ));
    st.admitted = true;
    st
}

struct ComboResult {
    admitted_per_s: f64,
    rounds_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    lp_per_round: f64,
    shard_migrations: usize,
    parked: usize,
}

/// Admit `n` coflows in one batch, then time `rounds` steady-state
/// decision cycles (one arrival each). The populate round is untimed.
fn bench_combo(wan: &Wan, n: usize, shards: usize, rounds: usize) -> ComboResult {
    let policy = TerraPolicy::new(TerraConfig { k: 1, ..Default::default() });
    let cfg = EngineConfig { check_feasibility: false, shards, ..Default::default() };
    let mut engine = ShardedEngine::new(wan.clone(), Box::new(policy), cfg);
    let pairs: Vec<(usize, usize)> = wan.links().iter().map(|l| (l.src, l.dst)).collect();
    let mut rng = Pcg32::new(0x5CA1E + n as u64 + shards as u64);

    let t0 = Instant::now();
    for i in 0..n {
        engine.insert(mk_state(i as u64 + 1, &pairs, &mut rng));
    }
    let admit_s = t0.elapsed().as_secs_f64();
    engine.round(0.0, RoundTrigger::Initial);
    engine.take_stats(); // drop populate-round counters

    let mut lat = Vec::with_capacity(rounds);
    let mut now = 0.0;
    for r in 0..rounds {
        engine.drain(0.05, 0.0);
        now += 0.05;
        let st = mk_state((n + r) as u64 + 1, &pairs, &mut rng);
        let t0 = Instant::now();
        engine.insert(st);
        engine.round(now, RoundTrigger::CoflowArrival);
        lat.push(t0.elapsed().as_secs_f64());
    }
    let st = engine.take_stats();
    let total: f64 = lat.iter().sum();
    ComboResult {
        admitted_per_s: if admit_s > 0.0 { n as f64 / admit_s } else { f64::INFINITY },
        rounds_per_s: if total > 0.0 { rounds as f64 / total } else { f64::INFINITY },
        p50_ms: 1e3 * stats::percentile(&lat, 50.0),
        p99_ms: 1e3 * stats::percentile(&lat, 99.0),
        lp_per_round: st.lp_solves as f64 / rounds as f64,
        shard_migrations: st.shard_migrations,
        parked: engine.parked(),
    }
}

fn combo_json(shards: usize, c: &ComboResult) -> Json {
    Json::from_pairs([
        ("shards", Json::from(shards)),
        ("admitted_per_s", c.admitted_per_s.into()),
        ("rounds_per_s", c.rounds_per_s.into()),
        ("p50_decision_ms", c.p50_ms.into()),
        ("p99_decision_ms", c.p99_ms.into()),
        ("lp_solves_per_round", c.lp_per_round.into()),
        ("shard_migrations", c.shard_migrations.into()),
        ("parked", c.parked.into()),
    ])
}

fn main() {
    let quick = quick_mode();
    let scales: Vec<usize> =
        if quick { vec![10_000, 100_000] } else { vec![10_000, 100_000, 1_000_000] };
    let rounds = if quick { 6 } else { 8 };
    let all = default_workers();
    let mut shard_axis = vec![1usize, 2, all];
    shard_axis.sort_unstable();
    shard_axis.dedup();
    let s_max = *shard_axis.last().unwrap();
    let topos: Vec<(&str, Wan)> = vec![
        ("swan", topologies::swan()),
        ("gscale", topologies::gscale()),
        ("att", topologies::att()),
    ];

    let mut topo_docs = Vec::new();
    for (tname, wan) in &topos {
        let mut tab = Table::new(&[
            "active",
            "1-shard p50",
            &format!("{s_max}-shard p50"),
            "p50 speedup",
            "p99 speedup",
            "1-shard adm/s",
            &format!("{s_max}-shard adm/s"),
            "rounds/s",
        ]);
        let mut scale_docs = Vec::new();
        for &n in &scales {
            let results: Vec<ComboResult> =
                shard_axis.iter().map(|&s| bench_combo(wan, n, s, rounds)).collect();
            let base = &results[0];
            let wide = results.last().unwrap();
            let sp50 = if wide.p50_ms > 0.0 { base.p50_ms / wide.p50_ms } else { f64::INFINITY };
            let sp99 = if wide.p99_ms > 0.0 { base.p99_ms / wide.p99_ms } else { f64::INFINITY };
            tab.row(&[
                n.to_string(),
                format!("{:.2}ms", base.p50_ms),
                format!("{:.2}ms", wide.p50_ms),
                format!("{sp50:.2}x"),
                format!("{sp99:.2}x"),
                format!("{:.0}", base.admitted_per_s),
                format!("{:.0}", wide.admitted_per_s),
                format!("{:.1}", wide.rounds_per_s),
            ]);
            let combos: Vec<Json> =
                shard_axis.iter().zip(&results).map(|(&s, c)| combo_json(s, c)).collect();
            scale_docs.push(Json::from_pairs([
                ("active_coflows", Json::from(n)),
                ("p50_decision_speedup_vs_single_shard", sp50.into()),
                ("p99_decision_speedup_vs_single_shard", sp99.into()),
                (
                    "admission_speedup_vs_single_shard",
                    (if base.admitted_per_s > 0.0 {
                        wide.admitted_per_s / base.admitted_per_s
                    } else {
                        f64::INFINITY
                    })
                    .into(),
                ),
                ("single_shard", combo_json(1, base)),
                ("sharded", combo_json(s_max, wide)),
                ("shard_counts", Json::Arr(combos)),
            ]));
        }
        tab.print(&format!("{tname}: decision latency and throughput by shard count"));
        topo_docs.push(Json::from_pairs([
            ("topology", Json::from(*tname)),
            ("scales", Json::Arr(scale_docs)),
        ]));
    }
    let doc = Json::from_pairs([
        ("workload", Json::from("pod-local single-group coflows on adjacent pairs, k=1")),
        ("rounds_timed", rounds.into()),
        ("arrivals_per_round", 1u64.into()),
        ("available_workers", all.into()),
        ("shard_axis", Json::Arr(shard_axis.iter().map(|&s| Json::from(s)).collect())),
        ("topologies", Json::Arr(topo_docs)),
    ]);
    let path = "BENCH_control_scale.json";
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
