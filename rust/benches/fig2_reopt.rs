//! Bench: regenerate Figure 2 (re-optimization under failure).
use terra::experiments::fig2_reopt;
use terra::util::bench::{report, time_n, Table};

fn main() {
    let mut rows = Vec::new();
    let t = time_n(1, 5, || rows = fig2_reopt());
    report("fig2_reopt", &t);
    let mut tab = Table::new(&["scenario", "avg CCT (s)", "paper (s)"]);
    let paper = [8.0, 14.0];
    for ((name, cct), p) in rows.iter().zip(paper) {
        tab.row(&[name.clone(), format!("{cct:.2}"), format!("{p:.2}")]);
    }
    tab.print("Figure 2: application-aware re-optimization");
}
