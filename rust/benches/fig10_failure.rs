//! Bench: regenerate Figures 9+10 (failure handling case study) on the
//! flow-level simulator: two jobs, LA-NY link fails and recovers; report the
//! per-job throughput timeline and reaction behaviour.
use terra::coflow::{Flow, GB};
use terra::net::{topologies, LinkEvent};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::sim::{Job, SimConfig, Simulation};
use terra::util::bench::{report, time_n, Table};

fn main() {
    let t = time_n(0, 3, || run(false));
    report("fig10_failure", &t);
    run(true);
}

fn run(print: bool) {
    // SWAN topology; job1 small (high priority), job2 large.
    let wan = topologies::swan();
    // alpha=0 for exposition, per the paper's case study.
    let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
    let mut sim = Simulation::new(wan, Box::new(policy), SimConfig::default());
    sim.add_job(Job::map_reduce(
        1,
        0.0,
        0.0,
        vec![Flow { id: 0, src_dc: 1, dst_dc: 0, volume: 20.0 * GB }], // LA -> NY
    ));
    sim.add_job(Job::map_reduce(
        2,
        0.0,
        0.0,
        vec![Flow { id: 0, src_dc: 1, dst_dc: 0, volume: 60.0 * GB }],
    ));
    sim.add_wan_event(3.0, LinkEvent::Fail(0, 1)); // NY-LA direct fails
    sim.add_wan_event(20.0, LinkEvent::Recover(0, 1));
    // Sample throughput timeline.
    let mut tab = Table::new(&["t (s)", "job1 Gbps", "job2 Gbps"]);
    let mut samples = Vec::new();
    for step in 0..30 {
        let t = step as f64 * 1.5;
        sim.run_until(t);
        samples.push((t, sim.coflow_rate(1), sim.coflow_rate(2)));
    }
    let rep = sim.run();
    if print {
        for (t, r1, r2) in &samples {
            tab.row(&[format!("{t:.1}"), format!("{r1:.1}"), format!("{r2:.1}")]);
        }
        tab.print("Figure 10: throughput during failure (fail@3s, recover@20s)");
        println!(
            "JCTs: job1 {:.1}s, job2 {:.1}s (job1 protected by preempting job2 on failure)",
            rep.jobs[0].jct().unwrap_or(f64::NAN),
            rep.jobs[1].jct().unwrap_or(f64::NAN)
        );
    }
}
