//! Bench: the three Optimization (1) backends (simplex, GK, JAX/PJRT) on
//! identical instances — the L1/L2/L3 solver-latency comparison backing the
//! §Perf analysis.
use terra::lp::{self, GroupDemand, McfInstance, SolverKind};
use terra::net::paths::PathSet;
use terra::net::topologies;
use terra::util::bench::{report, time_n};
use terra::util::rng::Pcg32;

fn instance(wan: &terra::net::Wan, paths: &PathSet, ng: usize, seed: u64) -> McfInstance {
    let mut rng = Pcg32::new(seed);
    let mut groups = Vec::new();
    for _ in 0..ng {
        let s = rng.below(wan.num_nodes());
        let mut d = rng.below(wan.num_nodes());
        while d == s {
            d = rng.below(wan.num_nodes());
        }
        groups.push(GroupDemand {
            volume: rng.uniform(10.0, 400.0),
            paths: paths.get(s, d).iter().map(|p| p.edges.clone()).collect(),
        });
    }
    McfInstance { cap: wan.capacities(), groups }
}

fn main() {
    for (tname, wan) in [("swan", topologies::swan()), ("att", topologies::att())] {
        let paths = PathSet::compute(&wan, 15);
        for ng in [4, 16, 48] {
            let inst = instance(&wan, &paths, ng, 42);
            let t = time_n(2, 20, || {
                lp::max_concurrent(&inst, SolverKind::Gk).unwrap();
            });
            report(&format!("{tname}/K={ng} garg-koenemann"), &t);
            if ng <= 16 {
                let t = time_n(1, 5, || {
                    lp::max_concurrent(&inst, SolverKind::Simplex).unwrap();
                });
                report(&format!("{tname}/K={ng} simplex"), &t);
            }
        }
    }
    // JAX/PJRT artifact (if built).
    if let Ok(solver) = terra::runtime::JaxSolver::load("artifacts") {
        let wan = topologies::swan();
        let paths = PathSet::compute(&wan, 15);
        for ng in [4, 16] {
            let inst = instance(&wan, &paths, ng, 42);
            let t = time_n(2, 10, || {
                solver.solve(&wan, &inst).unwrap();
            });
            report(&format!("swan/K={ng} jax-pdhg (PJRT, {} iters)", solver.iters), &t);
        }
    } else {
        println!("(artifacts not built; skipping JAX solver bench — run `make artifacts`)");
    }
}
