//! Bench: regenerate Figure 12 (k-path sensitivity on ATT) + §6.7 alpha.
use terra::experiments::{alpha_sensitivity, fig12_paths};
use terra::util::bench::{quick_mode, report, time_n, Table};
use terra::workloads::WorkloadKind;

fn main() {
    let jobs = if quick_mode() { 10 } else { 100 };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = fig12_paths(jobs, 42, WorkloadKind::BigBench));
    report("fig12_paths", &t);
    let mut tab = Table::new(&["k", "FoI avg JCT", "FoI util"]);
    for r in &rows {
        tab.row(&[r.k.to_string(), format!("{:.2}x", r.foi_avg_jct), format!("{:.2}x", r.foi_util)]);
    }
    tab.print("Figure 12: path restriction on ATT (gains flatten at k=5-10)");

    let alphas = alpha_sensitivity(jobs, 42);
    let mut tab = Table::new(&["alpha", "avg JCT (s)"]);
    for (a, jct) in &alphas {
        tab.row(&[format!("{a:.1}"), format!("{jct:.1}")]);
    }
    tab.print("§6.7: alpha sensitivity (paper: 0.2 is 2.3% worse than 0.1)");
}
