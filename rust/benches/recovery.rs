//! Bench: the controller-chaos recovery sweep — dynamics profiles
//! (calm / regional outages / gray failures) × controller availability
//! modes (always-up / resync reconstruction / restart-from-zero) on
//! SWAN + BigBench, reporting the in-flight fraction preserved across
//! the restart, the degraded-mode drain, the reconstruction-round cost,
//! and CCT inflation vs the always-up controller. Results are written to
//! `BENCH_recovery.json` (same schema as `terra sweep --recovery`).

use terra::experiments::{recovery_json, recovery_sweep, RecoverySweepConfig};
use terra::util::bench::{quick_mode, report, time_n, Table};

fn main() {
    let cfg = RecoverySweepConfig {
        jobs: if quick_mode() { 2 } else { 4 },
        horizon_s: if quick_mode() { 160.0 } else { 240.0 },
        kill_t: 20.0,
        restart_t: 25.0,
        ..Default::default()
    };
    let mut rows = Vec::new();
    let t = time_n(0, 1, || rows = recovery_sweep(&cfg));
    report("recovery_sweep", &t);

    let mut tab = Table::new(&[
        "profile", "mode", "avg CCT", "vs up", "preserved", "degraded Gbit", "down s",
        "recover ms", "unfin",
    ]);
    for r in &rows {
        tab.row(&[
            r.profile.clone(),
            r.mode.clone(),
            format!("{:.1}s", r.avg_cct),
            format!("{:.2}x", r.cct_vs_always_up),
            format!("{:.0}%", r.preserved_fraction * 100.0),
            format!("{:.1}", r.drained_degraded_gbit),
            format!("{:.1}", r.downtime_s),
            format!("{:.2}", r.recovery_round_ms),
            r.unfinished.to_string(),
        ]);
    }
    tab.print("Recovery sweep: surviving the controller crash");

    let json = format!("{}\n", recovery_json(&cfg, &rows));
    std::fs::write("BENCH_recovery.json", json).expect("write BENCH_recovery.json");
    println!("wrote BENCH_recovery.json ({} rows)", rows.len());
}
